"""CI smoke client for the HTTP front door (see ci.yml server-smoke job).

Waits for /healthz, streams one SSE completion, checks /metrics counted
it, and exits 0.  Stdlib only: http.client against a localhost port.

Usage: python .github/scripts/server_smoke.py PORT
"""
import http.client
import json
import sys
import time

PORT = int(sys.argv[1]) if len(sys.argv) > 1 else 8123


def req(method, path, body=None):
    conn = http.client.HTTPConnection("127.0.0.1", PORT, timeout=120)
    payload = json.dumps(body) if body is not None else None
    conn.request(method, path, body=payload,
                 headers={"Content-Type": "application/json"})
    resp = conn.getresponse()
    data = resp.read()
    conn.close()
    return resp.status, data


def main():
    # the first compile of the jitted decode step happens server-side; give
    # the listener (which binds before the engine warms) time to appear
    deadline = time.time() + 300
    while True:
        try:
            status, data = req("GET", "/healthz")
            if status == 200 and json.loads(data)["ok"]:
                break
        except OSError:
            pass
        if time.time() > deadline:
            sys.exit("server never became healthy")
        time.sleep(1)
    print("healthz ok")

    status, data = req("POST", "/v1/completions",
                       {"prompt": list(range(1, 13)), "max_tokens": 6,
                        "stream": True})
    assert status == 200, (status, data[:200])
    events = [ln for ln in data.decode().split("\n\n")
              if ln.startswith("data: ")]
    assert events[-1] == "data: [DONE]", events[-1]
    chunks = [json.loads(e[len("data: "):]) for e in events[:-1]]
    tokens = [t for c in chunks for t in c["choices"][0]["token_ids"]]
    assert len(tokens) == 6, tokens
    usage = chunks[-1]["usage"]
    assert usage["completion_tokens"] == 6, usage
    assert usage["slo_met"] is True, usage   # --slo-steps 64 default
    print(f"streamed completion ok: {tokens}")

    status, data = req("GET", "/metrics")
    assert status == 200
    snap = json.loads(data)
    assert snap["totals"]["requests_finished"] == 1, snap["totals"]
    assert snap["totals"]["tokens_out"] == 6, snap["totals"]
    assert snap["totals"]["slo_met"] == 1, snap["totals"]
    assert snap["engine"]["active_slots"] == 0, snap["engine"]
    print("metrics ok:", json.dumps(snap["totals"]))


if __name__ == "__main__":
    main()
