"""Table II — Sparse BitNet vs BitNet vs FP LLaMA (tiny-scale replication).

Trains four tiny LLaMA-family models on the synthetic corpus and reports
held-out PPL.  Expected ordering (paper): FP <= ternary <= +DAS <= +DAS+LPSA,
with small deltas — the qualitative claim "ternary + sparsity costs little".
"""
import os

from benchmarks.common import tiny_lm, train_eval_ppl

STEPS = int(os.environ.get("BENCH_STEPS", "200"))


def run():
    rows = []
    variants = [
        ("fp-llama", dict(ternary=False, das=False, lpsa=False)),
        ("bitnet", dict(ternary=True, das=False, lpsa=False)),
        ("bitnet+das", dict(ternary=True, das=True, lpsa=False)),
        ("bitnet+das+lpsa", dict(ternary=True, das=True, lpsa=True)),
    ]
    for name, kw in variants:
        cfg = tiny_lm(name, **kw)
        r = train_eval_ppl(cfg, steps=STEPS)
        rows.append({"name": f"table2/{name}",
                     "us_per_call": r["train_s"] * 1e6 / STEPS,
                     "derived": f"ppl={r['ppl']:.2f};loss={r['final_loss']:.3f}"})
    return rows
