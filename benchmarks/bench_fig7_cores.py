"""Fig 7 — area/power proxy of the four A8W1.58 cores + measured microbench.

Gate-level synthesis doesn't transfer to TPU (DESIGN.md §2); this bench
(i) reproduces the paper's *relative* area/power ordering from the Table-I
complexity model with weights calibrated so add-only is the 1.0 reference,
and (ii) wall-clocks the software analogues on this host.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core import twd
from repro.core.stl import core_complexity, stl_matmul_ref
from repro.kernels import ops

# per-unit cost weights calibrated against the paper's 28nm synthesis
# (Fig 7: STL -52% area / -46% power vs add-only; bitwise & base-3 LUT cores
# save power but little area): full adders dominate, mux/lookup logic is
# cheap, table registers sit in between.
W_AREA = {"precompute": 1.0, "lookup": 0.04, "adder": 1.0}
W_POWER = {"precompute": 1.0, "lookup": 0.10, "adder": 1.0}


def _score(core, sa, w):
    c = core_complexity(core, n_t=64, g_total=16, g=2, s_a=sa)
    return sum(w[k] * v for k, v in c.items())


def run():
    rows = []
    base_a = _score("add_only", 1.0, W_AREA)
    base_p = _score("add_only", 1.0, W_POWER)
    for core, sa in [("add_only", 1.0), ("general_lut", 1.0),
                     ("ternary_lut", 1.0), ("stl", 1.0), ("stl", 0.5),
                     ("stl", 0.25)]:
        a = _score(core, sa, W_AREA) / base_a
        p = _score(core, sa, W_POWER) / base_p
        rows.append({"name": f"fig7/{core}@Sa={sa}", "us_per_call": 0.0,
                     "derived": f"area_rel={a:.2f};power_rel={p:.2f}"})

    # measured: dense f32 vs STL-route vs fused packed kernel (interpret)
    rng = np.random.default_rng(0)
    k, n, m = 640, 256, 8
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    wt = jnp.asarray(rng.integers(-1, 2, (k, n)), jnp.int8)
    packed = jnp.asarray(twd.pack_ternary(wt))
    f_dense = jax.jit(lambda a, b: a @ b)
    f_stl = jax.jit(stl_matmul_ref)
    t_dense = time_fn(f_dense, x, wt.astype(jnp.float32))
    t_stl = time_fn(f_stl, x, wt)
    f_pk = jax.jit(lambda a, p_: ops.ternary_gemm(a, p_, 1.0, mode="ref"))
    t_pk = time_fn(f_pk, x, packed)
    rows.append({"name": "fig7/measured_dense_f32", "us_per_call": t_dense,
                 "derived": "host-cpu"})
    rows.append({"name": "fig7/measured_stl_route", "us_per_call": t_stl,
                 "derived": f"vs_dense={t_stl/t_dense:.2f}x"})
    rows.append({"name": "fig7/measured_packed_gemm", "us_per_call": t_pk,
                 "derived": f"vs_dense={t_pk/t_dense:.2f}x"})
    return rows
