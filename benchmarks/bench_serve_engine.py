"""Serving engine — continuous batching vs lock-step on a Poisson trace.

Replays one deterministic Poisson arrival trace (exponential inter-arrival
gaps in virtual decode steps, mixed prompt/generation lengths) through
`repro.serve.ServeEngine` under both admission policies:

  * wave       — lock-step gang scheduling (admit only when every slot is
                 free, barrier until the whole wave finishes): the old
                 shared-position serving model.
  * continuous — per-slot admission/retirement over per-sequence KV state.

plus the block-paged KV pool (ServeConfig(layout="paged")) on full-attention
caches:

  * paged        — the same Poisson trace through the paged engine, with a
                   bitwise token-parity check against a per-slot dense
                   engine at the same max_len, reporting peak pool memory
                   (pages x per-page bytes) next to µs/step.
  * paged_prefix — a shared-prefix trace (common prompt stem) where the
                   radix trie must absorb strictly fewer prompt tokens via
                   prefill than the sharing-disabled engine.

Reports decode tok/s and p50/p95 per-request latency (in virtual decode
steps, so the comparison is deterministic) plus the measured wall-clock
throughput ratio.  Set TENET_POOL_METRICS=<path> to drop the paged pool
occupancy stats as JSON (CI uploads it as an artifact).
"""
import json
import os

import numpy as np

from benchmarks.common import tiny_hybrid, tiny_lm
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.serve import Request, ServeConfig, ServeEngine

SLOTS = 4
N_REQ = 12
MEAN_GAP = 3.0       # mean inter-arrival, virtual decode steps
PAGE = 8
PAGED_MAX_LEN = 72   # trace worst case (47 + 19) rounded up to a page


def poisson_trace(cfg, n=N_REQ, seed=0):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(MEAN_GAP, n)).astype(int)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(12, 48))
        gen = int(rng.integers(6, 20))
        reqs.append(Request(
            uid=i, prompt=np.asarray(rng.integers(0, cfg.vocab, plen),
                                     np.int32),
            max_new_tokens=gen, arrival=int(arrivals[i])))
    return reqs


def bursty_trace(cfg, seed=2):
    """Bursts of loose-SLO bulk work with tight-SLO interactive requests
    landing mid-burst: the workload where FIFO (arrival order) makes the
    interactive requests queue behind the whole burst and miss, while
    deadline scheduling slots them in first."""
    rng = np.random.default_rng(seed)
    reqs, uid = [], 0
    for b in range(3):
        t0 = b * 40
        for _ in range(6):          # bulk burst, generous deadline
            plen = int(rng.integers(16, 40))
            gen = int(rng.integers(10, 18))
            reqs.append(Request(
                uid=uid, prompt=np.asarray(rng.integers(0, cfg.vocab, plen),
                                           np.int32),
                max_new_tokens=gen, arrival=t0, slo_steps=150))
            uid += 1
        for j in range(2):          # interactive, tight deadline
            plen = int(rng.integers(4, 10))
            reqs.append(Request(
                uid=uid, prompt=np.asarray(rng.integers(0, cfg.vocab, plen),
                                           np.int32),
                max_new_tokens=4, arrival=t0 + 2 + 4 * j, slo_steps=22))
            uid += 1
    return reqs


def _run_slo(cfg, sparams, rt, max_len):
    """SLO attainment on the bursty trace: deadline scheduling (with
    preemption) must meet at least as many deadlines as the FIFO
    baseline."""
    res = {}
    for name, sched_kw in (
            ("fifo", dict(scheduler="fifo")),
            ("deadline", dict(scheduler="deadline", preemption=True))):
        eng = ServeEngine(cfg, sparams, rt,
                          config=ServeConfig(max_slots=SLOTS,
                                             max_len=max_len, **sched_kw))
        results = eng.timed_replay(bursty_trace(cfg))
        res[name] = {**_summarize(eng, results),
                     "slo": _attainment(results),
                     "preempt": eng.stats.preemptions}
    assert res["deadline"]["slo"] >= res["fifo"]["slo"], \
        (f"deadline scheduling met fewer SLOs than FIFO: "
         f"{res['deadline']['slo']:.2f} < {res['fifo']['slo']:.2f}")
    return res


def shared_prefix_trace(cfg, n=8, stem=32, tail=6, seed=1):
    """n prompts sharing a stem-token prefix, arriving far enough apart
    that the first finishes registering before the rest hit the trie."""
    rng = np.random.default_rng(seed)
    stem_toks = rng.integers(0, cfg.vocab, stem)
    reqs = []
    for i in range(n):
        prompt = np.concatenate([stem_toks,
                                 rng.integers(0, cfg.vocab, tail)])
        reqs.append(Request(uid=i, prompt=np.asarray(prompt, np.int32),
                            max_new_tokens=8, arrival=6 * i))
    return reqs


def _summarize(eng, results):
    # guard the empty trace: np.percentile on a zero-length array raises
    lat = np.asarray([r.latency_steps for r in results.values()])
    st = eng.stats
    return {
        "tok_s": st.generated_tokens / max(st.wall_seconds, 1e-9),
        "p50": float(np.percentile(lat, 50)) if lat.size else 0.0,
        "p95": float(np.percentile(lat, 95)) if lat.size else 0.0,
        "steps": st.decode_steps,
        "util": st.slot_utilization,
        "wall_us": st.wall_seconds * 1e6,
    }


def _attainment(results):
    """Fraction of SLO-tracked requests finishing within their deadline."""
    tracked = [r for r in results.values() if r.slo_steps is not None]
    return sum(r.slo_met for r in tracked) / max(len(tracked), 1)


def _run_policy(cfg, sparams, rt, policy, max_len):
    eng = ServeEngine(cfg, sparams, rt,
                      config=ServeConfig(max_slots=SLOTS, max_len=max_len,
                                         policy=policy))
    return _summarize(eng, eng.timed_replay(poisson_trace(cfg)))


def _run_paged(cfg, sparams):
    """Paged vs per-slot dense on full-attention caches (serve_sparse off
    keeps the global layers full so they become page arenas)."""
    rt = Runtime(serve_sparse=False)
    dense = ServeEngine(cfg, sparams, rt,
                        config=ServeConfig(max_slots=SLOTS,
                                           max_len=PAGED_MAX_LEN))
    ref = dense.timed_replay(poisson_trace(cfg))
    # prefix sharing off: random prompts share nothing, and without trie
    # retention the pool-peak metric shows pure lazy allocation (used
    # memory ~ live tokens); the shared-prefix row covers the trie
    paged = ServeEngine(cfg, sparams, rt,
                        config=ServeConfig(max_slots=SLOTS,
                                           max_len=PAGED_MAX_LEN,
                                           layout="paged", page_size=PAGE,
                                           prefix_sharing=False))
    got = paged.timed_replay(poisson_trace(cfg))
    for uid in ref:   # paged must be a pure layout change, not a new model
        assert np.array_equal(ref[uid].tokens, got[uid].tokens), \
            f"paged tokens diverged from per-slot dense for uid {uid}"
    return paged, _summarize(paged, got)


def _run_prefix(cfg, sparams):
    rt = Runtime(serve_sparse=False)
    engines = {}
    for share in (True, False):
        eng = ServeEngine(cfg, sparams, rt,
                          config=ServeConfig(max_slots=SLOTS,
                                             max_len=PAGED_MAX_LEN,
                                             layout="paged", page_size=PAGE,
                                             prefix_sharing=share))
        for r in shared_prefix_trace(cfg):
            eng.submit(r)
        eng.run()
        engines[share] = eng
    on, off = engines[True], engines[False]
    assert on.stats.prefill_tokens < off.stats.prefill_tokens, \
        "prefix sharing failed to reduce prefilled prompt tokens"
    return on, off


def _run_recurrent():
    """Continuous batching over a mamba/attn hybrid: per-slot recurrent
    state (ssm carry + chunk-replay buffers) rides next to the LPSA ring
    in one slot-state pytree.  Sanity: the same trace through the wave
    (gang-scheduled) engine must yield bitwise-identical tokens — a
    request's stream cannot depend on how it was batched."""
    cfg = tiny_hybrid("serve-bench-hybrid", d_model=128, n_layers=4)
    params = MD.init_params(__import__("jax").random.PRNGKey(0), cfg)
    sparams = MD.export_serving(params, cfg)
    rt = Runtime()
    max_len = 48 + 20
    cont = ServeEngine(cfg, sparams, rt,
                       config=ServeConfig(max_slots=SLOTS, max_len=max_len))
    got = cont.timed_replay(poisson_trace(cfg))
    wave = ServeEngine(cfg, sparams, rt,
                       config=ServeConfig(max_slots=SLOTS, max_len=max_len,
                                          policy="wave"))
    ref = wave.timed_replay(poisson_trace(cfg))
    for uid in ref:
        assert np.array_equal(ref[uid].tokens, got[uid].tokens), \
            f"hybrid tokens depend on batching for uid {uid}"
    return _summarize(cont, got)


def run():
    cfg = tiny_lm("serve-bench", d_model=128, n_layers=4, window=48, sink=8)
    params = MD.init_params(__import__("jax").random.PRNGKey(0), cfg)
    sparams = MD.export_serving(params, cfg)
    rt = Runtime()
    max_len = 48 + 20  # prompt + gen upper bounds of the trace

    rows, res = [], {}
    for policy in ("wave", "continuous"):
        r = _run_policy(cfg, sparams, rt, policy, max_len)
        res[policy] = r
        rows.append({
            "name": f"serve/{policy}",
            "us_per_call": r["wall_us"] / max(r["steps"], 1),
            "derived": (f"tok_s={r['tok_s']:.1f};p50={r['p50']:.0f};"
                        f"p95={r['p95']:.0f};util={r['util']:.2f};"
                        f"steps={r['steps']}"),
        })
    w, c = res["wave"], res["continuous"]
    rows.append({
        "name": "serve/continuous_vs_lockstep", "us_per_call": 0.0,
        "derived": (f"tok_s={c['tok_s']/max(w['tok_s'],1e-9):.2f}x;"
                    f"p50={w['p50']/max(c['p50'],1e-9):.2f}x;"
                    f"p95={w['p95']/max(c['p95'],1e-9):.2f}x"),
    })

    paged_eng, pr = _run_paged(cfg, sparams)
    pool = paged_eng.pool_stats()
    rows.append({
        "name": "serve/paged",
        "us_per_call": pr["wall_us"] / max(pr["steps"], 1),
        "derived": (f"tok_s={pr['tok_s']:.1f};util={pr['util']:.2f};"
                    f"pool_peak_kb={pool['bytes_peak']/1e3:.1f};"
                    f"dense_kb={pool['dense_equiv_bytes']/1e3:.1f};"
                    f"pages_peak={pool['pages_peak']}/"
                    f"{pool['num_pages']};"
                    f"cow={paged_eng.stats.cow_copies}"),
    })

    slo = _run_slo(cfg, sparams, rt, max_len)
    d, f = slo["deadline"], slo["fifo"]
    rows.append({
        "name": "serve/slo_deadline",
        "us_per_call": d["wall_us"] / max(d["steps"], 1),
        "derived": (f"slo_attain={d['slo']:.2f};p95={d['p95']:.0f};"
                    f"preempt={d['preempt']};tok_s={d['tok_s']:.1f};"
                    f"steps={d['steps']}"),
    })
    rows.append({
        "name": "serve/slo_attainment", "us_per_call": 0.0,
        "derived": (f"deadline={d['slo']:.2f};fifo={f['slo']:.2f};"
                    f"p95_deadline={d['p95']:.0f};p95_fifo={f['p95']:.0f};"
                    f"preemptions={d['preempt']}"),
    })

    rr = _run_recurrent()
    rows.append({
        "name": "serve/recurrent",
        "us_per_call": rr["wall_us"] / max(rr["steps"], 1),
        "derived": (f"tok_s={rr['tok_s']:.1f};p50={rr['p50']:.0f};"
                    f"p95={rr['p95']:.0f};util={rr['util']:.2f};"
                    f"steps={rr['steps']};parity=wave_bitwise"),
    })

    on, off = _run_prefix(cfg, sparams)
    saved = off.stats.prefill_tokens - on.stats.prefill_tokens
    st = on.stats
    rows.append({
        "name": "serve/paged_prefix", "us_per_call": 0.0,
        "derived": (f"prefill_saved={saved};hits={st.prefix_hits};"
                    f"reused={st.prompt_tokens_reused};"
                    f"prefill_on={st.prefill_tokens};"
                    f"prefill_off={off.stats.prefill_tokens}"),
    })

    metrics_path = os.environ.get("TENET_POOL_METRICS")
    if metrics_path:
        with open(metrics_path, "w") as f:
            json.dump({
                "poisson": {**pool, "cow_copies": paged_eng.stats.cow_copies,
                            "prefix_hits": paged_eng.stats.prefix_hits},
                "shared_prefix": {
                    **on.pool_stats(),
                    "prefill_tokens_sharing_on": st.prefill_tokens,
                    "prefill_tokens_sharing_off": off.stats.prefill_tokens,
                    "prompt_tokens_reused": st.prompt_tokens_reused,
                    "prefix_hits": st.prefix_hits,
                    "prefix_evictions": st.prefix_evictions,
                },
            }, f, indent=2)
    return rows
