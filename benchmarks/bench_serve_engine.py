"""Serving engine — continuous batching vs lock-step on a Poisson trace.

Replays one deterministic Poisson arrival trace (exponential inter-arrival
gaps in virtual decode steps, mixed prompt/generation lengths) through
`repro.serve.ServeEngine` under both admission policies:

  * wave       — lock-step gang scheduling (admit only when every slot is
                 free, barrier until the whole wave finishes): the old
                 shared-position serving model.
  * continuous — per-slot admission/retirement over per-sequence KV state.

Reports decode tok/s and p50/p95 per-request latency (in virtual decode
steps, so the comparison is deterministic) plus the measured wall-clock
throughput ratio.
"""
import numpy as np

from benchmarks.common import tiny_lm
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.serve import Request, ServeEngine

SLOTS = 4
N_REQ = 12
MEAN_GAP = 3.0       # mean inter-arrival, virtual decode steps


def poisson_trace(cfg, n=N_REQ, seed=0):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(MEAN_GAP, n)).astype(int)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(12, 48))
        gen = int(rng.integers(6, 20))
        reqs.append(Request(
            uid=i, prompt=np.asarray(rng.integers(0, cfg.vocab, plen),
                                     np.int32),
            max_new_tokens=gen, arrival=int(arrivals[i])))
    return reqs


def _run_policy(cfg, sparams, rt, policy, max_len):
    eng = ServeEngine(cfg, sparams, rt, max_slots=SLOTS, max_len=max_len,
                      policy=policy)
    results = eng.timed_replay(poisson_trace(cfg))
    lat = np.asarray([r.latency_steps for r in results.values()])
    st = eng.stats
    return {
        "tok_s": st.generated_tokens / max(st.wall_seconds, 1e-9),
        "p50": float(np.percentile(lat, 50)),
        "p95": float(np.percentile(lat, 95)),
        "steps": st.decode_steps,
        "util": st.slot_utilization,
        "wall_us": st.wall_seconds * 1e6,
    }


def run():
    cfg = tiny_lm("serve-bench", d_model=128, n_layers=4, window=48, sink=8)
    params = MD.init_params(__import__("jax").random.PRNGKey(0), cfg)
    sparams = MD.export_serving(params, cfg)
    rt = Runtime()
    max_len = 48 + 20  # prompt + gen upper bounds of the trace

    rows, res = [], {}
    for policy in ("wave", "continuous"):
        r = _run_policy(cfg, sparams, rt, policy, max_len)
        res[policy] = r
        rows.append({
            "name": f"serve/{policy}",
            "us_per_call": r["wall_us"] / max(r["steps"], 1),
            "derived": (f"tok_s={r['tok_s']:.1f};p50={r['p50']:.0f};"
                        f"p95={r['p95']:.0f};util={r['util']:.2f};"
                        f"steps={r['steps']}"),
        })
    w, c = res["wave"], res["continuous"]
    rows.append({
        "name": "serve/continuous_vs_lockstep", "us_per_call": 0.0,
        "derived": (f"tok_s={c['tok_s']/max(w['tok_s'],1e-9):.2f}x;"
                    f"p50={w['p50']/max(c['p50'],1e-9):.2f}x;"
                    f"p95={w['p95']/max(c['p95'],1e-9):.2f}x"),
    })
    return rows
