"""Sharded serving — the GSPMD-safe decode path + elastic recovery cost.

Replays one deterministic staggered trace through the engine three ways:

  * ref      — the single-device reference kernel path (baseline).
  * sharded  — kernel_mode="sharded": the pad5 unpack-and-matmul path the
               Topology/ShardingPlan machinery jits with explicit in/out
               shardings on a real mesh.  On the 1-device bench host it
               measures the pure kernel-path overhead; token parity with
               ref is asserted (the sharded path must be a layout change,
               not a new model).
  * recovery — same trace with a WorkerFailure injected mid-decode:
               snapshot -> rebuild -> replay.  Reports the recovery
               latency and the replayed-step overhead next to the clean
               run; token parity with ref is asserted again (replay is
               bitwise).

On a multi-device host (XLA_FLAGS=--xla_force_host_platform_device_count=N)
set TENET_BENCH_TP/TENET_BENCH_DP to bench a real (dp, tp) mesh.
"""
import os

import numpy as np

from benchmarks.common import tiny_lm
from repro.distributed.fault import FaultInjector
from repro.distributed.plan import Topology
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.serve import Request, ServeConfig, ServeEngine

SLOTS = 4
N_REQ = 8
MAX_LEN = 48 + 20


def _trace(cfg, n=N_REQ, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(12, 48))
        gen = int(rng.integers(6, 20))
        reqs.append(Request(
            uid=i, prompt=np.asarray(rng.integers(0, cfg.vocab, plen),
                                     np.int32),
            max_new_tokens=gen, arrival=3 * i))
    return reqs


def _topology():
    tp = int(os.environ.get("TENET_BENCH_TP", "0"))
    dp = int(os.environ.get("TENET_BENCH_DP", "0"))
    if tp or dp:
        return Topology(dp=dp or 1, tp=tp or 1)
    return None


def _run(cfg, sparams, kernel_mode, *, topology=None, fail_at=None,
         lost=0):
    eng = ServeEngine(cfg, sparams, Runtime(kernel_mode=kernel_mode),
                      config=ServeConfig(max_slots=SLOTS, max_len=MAX_LEN,
                                         topology=topology))
    if fail_at is None:
        return eng, eng.timed_replay(_trace(cfg))
    # timed_replay by hand: warm the compile caches failure-free, then arm
    # the injector so the fault (and its recovery) lands in the timed run
    for r in _trace(cfg):
        eng.submit(r)
    eng.run()
    eng.reset_clock()
    eng.fault_injector = FaultInjector(fail_at=(fail_at,))
    eng.fault_lost_devices = lost
    for r in _trace(cfg):
        eng.submit(r)
    return eng, eng.run()


def _row(name, eng, results, extra=""):
    st = eng.stats
    return {
        "name": name,
        "us_per_call": st.wall_seconds * 1e6 / max(st.decode_steps, 1),
        "derived": (f"tok_s={st.generated_tokens/max(st.wall_seconds,1e-9):.1f};"
                    f"steps={st.decode_steps};util={st.slot_utilization:.2f}"
                    + (";" + extra if extra else "")),
    }


def run():
    cfg = tiny_lm("sharded-bench", d_model=128, n_layers=4, window=48,
                  sink=8)
    import jax
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    sparams = MD.export_serving(params, cfg)
    topo = _topology()

    ref_eng, ref = _run(cfg, sparams, "ref")
    sh_eng, sh = _run(cfg, sparams, "sharded", topology=topo)
    for uid in ref:   # sharded must be a layout change, not a new model
        assert np.array_equal(ref[uid].tokens, sh[uid].tokens), \
            f"sharded tokens diverged from ref for uid {uid}"

    # fail a third of the way through the clean run's decode steps
    fail_at = max(2, ref_eng.stats.decode_steps // 3)
    lost = (topo.n_devices // 2 if topo is not None else 0)
    rec_eng, rec = _run(cfg, sparams, "sharded", topology=topo,
                        fail_at=fail_at, lost=lost)
    for uid in ref:
        assert np.array_equal(ref[uid].tokens, rec[uid].tokens), \
            f"post-recovery tokens diverged from ref for uid {uid}"
    assert rec_eng.stats.reshards == 1

    ref_us = ref_eng.stats.wall_seconds * 1e6 / \
        max(ref_eng.stats.decode_steps, 1)
    sh_us = sh_eng.stats.wall_seconds * 1e6 / \
        max(sh_eng.stats.decode_steps, 1)
    t = rec_eng.topology
    return [
        _row("sharded/ref_baseline", ref_eng, ref),
        _row("sharded/sharded_path", sh_eng, sh,
             extra=(f"vs_ref={sh_us/max(ref_us,1e-9):.2f}x;parity=bitwise;"
                    + ("mesh=1dev" if topo is None
                       else f"dp={topo.dp};tp={topo.tp}"))),
        _row("sharded/recovery", rec_eng, rec,
             extra=(f"reshards={rec_eng.stats.reshards};"
                    f"recovery_ms={rec_eng.stats.recovery_seconds*1e3:.1f};"
                    f"replayed_steps="
                    f"{rec_eng.stats.decode_steps - sh_eng.stats.decode_steps};"
                    + ("topo=none" if t is None
                       else f"topo=dp{t.dp}xtp{t.tp}"))),
    ]
