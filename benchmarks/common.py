"""Shared benchmark helpers: timing + tiny-model training harness."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (DasConfig, LpsaConfig, ModelConfig,
                                SsmConfig, TernaryConfig)
from repro.data.pipeline import SyntheticLM
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.optim import adamw, schedule

RT = Runtime()


def time_fn(fn, *args, iters=5, warmup=2) -> float:
    """Median wall-time per call in microseconds (jit'd fn)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def tiny_lm(name="tiny", *, ternary=True, das=True, lpsa=True,
            d_model=128, n_layers=4, vocab=512, window=24, sink=8) -> ModelConfig:
    return ModelConfig(
        name=name, family="dense", n_layers=n_layers, d_model=d_model,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=d_model * 4, vocab=vocab,
        ternary=TernaryConfig(enabled=ternary,
                              das=DasConfig(32, 16) if das else None),
        lpsa=LpsaConfig(sink=sink, window=window, chunk=16) if lpsa else None,
        dtype="float32", remat=False, scan_layers=False,
    )


def tiny_hybrid(name="tiny-hybrid", *, d_model=128, n_layers=4,
                vocab=512, window=24, sink=8) -> ModelConfig:
    """Mamba/attention hybrid (zamba2-style pattern) for serving benches:
    the attn layers ride the LPSA ring, the mamba layers carry recurrent
    ssm state + chunk-replay buffers per slot."""
    return ModelConfig(
        name=name, family="hybrid", n_layers=n_layers, d_model=d_model,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=d_model * 4, vocab=vocab,
        layer_pattern=("mamba", "attn"),
        ternary=TernaryConfig(das=DasConfig(32, 16)),
        lpsa=LpsaConfig(sink=sink, window=window, chunk=16),
        ssm=SsmConfig(16, 16, 2, 4, chunk=16),
        dtype="float32", remat=False, scan_layers=False,
    )


def train_eval_ppl(cfg: ModelConfig, *, steps=250, batch=8, seq=64, lr=1e-2,
                   seed=0, eval_batches=4) -> dict:
    """Train on SyntheticLM, return final train loss + held-out PPL."""
    data = SyntheticLM(vocab=cfg.vocab, seq_len=seq, batch=batch, seed=seed)
    heldout = SyntheticLM(vocab=cfg.vocab, seq_len=seq, batch=batch,
                          seed=seed + 999)
    params = MD.init_params(jax.random.PRNGKey(seed), cfg)
    opt = adamw.adamw_init(params)

    @jax.jit
    def step_fn(p, o, b):
        (loss, _), g = jax.value_and_grad(
            lambda pp: MD.loss_fn(pp, cfg, b, RT), has_aux=True)(p)
        lr_t = schedule.cosine_schedule(o.step, peak_lr=lr, warmup=20,
                                        total=steps)
        p, o, _ = adamw.adamw_step(p, g, o, lr=lr_t)
        return p, o, loss

    t0 = time.perf_counter()
    first = last = None
    for s in range(steps):
        b = jax.tree.map(jnp.asarray, data.batch_at(s))
        params, opt, loss = step_fn(params, opt, b)
        if s == 0:
            first = float(loss)
        last = float(loss)
    train_s = time.perf_counter() - t0

    @jax.jit
    def eval_fn(p, b):
        return MD.loss_fn(p, cfg, b, RT)[0]

    nll = float(np.mean([float(eval_fn(params,
                                       jax.tree.map(jnp.asarray,
                                                    heldout.batch_at(i))))
                         for i in range(eval_batches)]))
    return {"first_loss": first, "final_loss": last, "eval_nll": nll,
            "ppl": float(np.exp(nll)), "train_s": train_s}
