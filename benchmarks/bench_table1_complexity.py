"""Table I — ternary compute-core complexity comparison (exact formulas)."""
from repro.core.stl import core_complexity


def run():
    rows = []
    kw = dict(n_t=64, g_total=16, g=2)
    for core, sa in [("add_only", 1.0), ("general_lut", 1.0),
                     ("ternary_lut", 1.0), ("stl", 1.0), ("stl", 0.5),
                     ("stl", 0.25)]:
        c = core_complexity(core, **kw, s_a=sa)
        total = sum(c.values())
        rows.append({"name": f"table1/{core}@Sa={sa}", "us_per_call": 0.0,
                     "derived": f"pre={c['precompute']:.0f};look={c['lookup']:.0f};"
                                f"add={c['adder']:.0f};total={total:.0f}"})
    return rows
