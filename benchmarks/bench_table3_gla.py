"""Table III — GLA + TQ + DAS (tiny-scale replication, paper Sec. V-D)."""
import dataclasses
import os

from benchmarks.common import train_eval_ppl
from repro.configs import get_config, reduced
from repro.configs.base import DasConfig

STEPS = int(os.environ.get("BENCH_STEPS", "200"))


def run():
    base = reduced(get_config("gla-1.3b"), d_model=128)
    rows = []
    variants = [
        ("gla-fp", dataclasses.replace(
            base, ternary=dataclasses.replace(base.ternary, enabled=False,
                                              das=None))),
        ("gla+tq", dataclasses.replace(
            base, ternary=dataclasses.replace(base.ternary, enabled=True,
                                              das=None))),
        ("gla+tq+das", dataclasses.replace(
            base, ternary=dataclasses.replace(base.ternary, enabled=True,
                                              das=DasConfig(32, 16)))),
    ]
    for name, cfg in variants:
        r = train_eval_ppl(cfg, steps=STEPS)
        rows.append({"name": f"table3/{name}",
                     "us_per_call": r["train_s"] * 1e6 / STEPS,
                     "derived": f"ppl={r['ppl']:.2f};loss={r['final_loss']:.3f}"})
    return rows
