"""Fig 17 — TENET vs SoTA accelerators (modeled simulators, aligned params).

BitFusion and LUT-Tensor-Core replace the HMVM engine at equal throughput but
without TWD packing (weights at 2b) and without LPSA fusion (attention
intermediates round-trip DRAM) — the paper attributes TENET's 1.49x speedup
and 1.57x energy edge to exactly those memory paths.
"""
from repro.core import perfmodel as pm


def run():
    m = pm.LLAMA_3B
    rows = []
    cfgs = {
        "bitfusion": pm.TenetOpt(weight_bits=2.0, das=False, lpsa=False),
        "lut-tensor-core": pm.TenetOpt(weight_bits=2.0, das=False, lpsa=False),
        "tenet": pm.TenetOpt.full(),
    }
    res = {}
    for name, opt in cfgs.items():
        res[name] = pm.e2e(m, pm.TENET_ASIC, opt, prefill_tl=512,
                           decode_tokens=512)
        rows.append({"name": f"fig17/{name}",
                     "us_per_call": res[name].latency_s * 1e6,
                     "derived": f"energy_j={res[name].energy_j:.3f}"})
    sp = res["bitfusion"].latency_s / res["tenet"].latency_s
    en = res["bitfusion"].energy_j / res["tenet"].energy_j
    rows.append({"name": "fig17/tenet_vs_sota", "us_per_call": 0.0,
                 "derived": f"speedup={sp:.2f}x;energy={en:.2f}x;paper=(1.49x,1.57x)"})
    return rows
