"""Fig 1/2 — Intelligence-Per-Joule across weight precisions (modeled).

Ternary (1.6b TWD) must maximize IPJ for decode-heavy workloads; the gap to
ideal on commodity HW (Fig 2) shows as the A100's low utilization share.
"""
from repro.core import perfmodel as pm

# PPL proxies per precision (paper Fig 1 assumes quality ~ FP16 baseline,
# with small quantization penalties)
PPL = {"fp16": 9.61, "int8": 9.65, "int4": 9.9, "ternary": 10.18}
BITS = {"fp16": 16.0, "int8": 8.0, "int4": 4.0, "ternary": 1.6}


def run():
    m = pm.LLAMA_7B
    rows = []
    best = None
    for name, bits in BITS.items():
        opt = pm.TenetOpt(weight_bits=bits, das=False,
                          lpsa=(name == "ternary"))
        r = pm.e2e(m, pm.TENET_ASIC, opt, prefill_tl=512, decode_tokens=512)
        val = r.ipj(PPL[name])
        best = max(best or 0, val)
        rows.append({"name": f"fig1/ipj/{name}", "us_per_call": 0.0,
                     "derived": f"ipj={val:.2f};tok_s={r.tokens_per_s:.0f}"})
    rows.append({"name": "fig1/ternary_is_best", "us_per_call": 0.0,
                 "derived": str(best == max(
                     pm.e2e(m, pm.TENET_ASIC,
                            pm.TenetOpt(weight_bits=b, lpsa=(n == 'ternary')),
                            prefill_tl=512, decode_tokens=512).ipj(PPL[n])
                     for n, b in BITS.items()))})
    return rows
