"""Fig 12 — prefill/decode speedups.

Two halves: (i) measured on this host — reduced BitNet served in naive-bf16
vs int8-resident vs packed(TWD)+LPSA modes; (ii) modeled (perfmodel) —
TENET-FPGA / TENET-ASIC / A100 over CPU at paper scale, reproducing the
Fig-12 ordering (TENET-ASIC ~27.9x CPU, ~2.7x A100).
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.core import perfmodel as pm


def _serve_once(cfg, rt, B=2, PRE=64, GEN=8, seed=0):
    params = MD.init_params(jax.random.PRNGKey(seed), cfg)
    sp = MD.export_serving(params, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, PRE + GEN), 0,
                              cfg.vocab)
    prefill = jax.jit(lambda s, x: MD.prefill(s, cfg, x, rt, max_len=PRE + GEN))
    decode = jax.jit(lambda s, c, tk, t: MD.decode_step(s, cfg, c, tk, t, rt))
    lg, caches = prefill(sp, toks[:, :PRE])          # compile
    jax.block_until_ready(lg)
    t0 = time.perf_counter()
    lg, caches = prefill(sp, toks[:, :PRE])
    jax.block_until_ready(lg)
    t_pre = time.perf_counter() - t0
    lg, caches2 = decode(sp, caches, toks[:, PRE], jnp.array(PRE))  # compile
    jax.block_until_ready(lg)
    t0 = time.perf_counter()
    c = caches
    for i in range(GEN):
        lg, c = decode(sp, c, toks[:, PRE + i], jnp.array(PRE + i))
    jax.block_until_ready(lg)
    t_dec = (time.perf_counter() - t0) / GEN
    return t_pre * 1e6, t_dec * 1e6


def run():
    rows = []
    base = reduced(get_config("bitnet-1.3b"))
    modes = {
        "naive-bf16": (dataclasses.replace(
            base, ternary=dataclasses.replace(base.ternary, enabled=False,
                                              das=None), lpsa=None),
            Runtime(serve_sparse=False)),
        "int8-resident": (dataclasses.replace(
            base, ternary=dataclasses.replace(base.ternary, das=None,
                                              serve_format="int8"),
            lpsa=None), Runtime(serve_sparse=False)),
        "twd+das+lpsa": (base, Runtime(serve_sparse=True)),
    }
    meas = {}
    for name, (cfg, rt) in modes.items():
        tp, td = _serve_once(cfg, rt)
        meas[name] = (tp, td)
        rows.append({"name": f"fig12/measured/{name}", "us_per_call": td,
                     "derived": f"prefill_us={tp:.0f};decode_us={td:.0f}"})
    b = meas["naive-bf16"]
    t = meas["twd+das+lpsa"]
    rows.append({"name": "fig12/measured/speedup", "us_per_call": 0.0,
                 "derived": f"prefill={b[0]/t[0]:.2f}x;decode={b[1]/t[1]:.2f}x"})

    # modeled at paper scale (BitNet-3B, 512/512 workload)
    m = pm.LLAMA_3B
    opt = pm.TenetOpt.full()
    res = {
        "cpu": pm.e2e(m, pm.CPU_I7, pm.TenetOpt.twd(), prefill_tl=512,
                      decode_tokens=512),
        "a100-naive": pm.e2e(m, pm.A100_NAIVE, pm.TenetOpt(weight_bits=16),
                             prefill_tl=512, decode_tokens=512),
        "a100-opt": pm.e2e(m, pm.A100_OPT, pm.TenetOpt(weight_bits=2),
                           prefill_tl=512, decode_tokens=512),
        "tenet-fpga": pm.e2e(m, pm.TENET_FPGA, opt, prefill_tl=512,
                             decode_tokens=512),
        "tenet-asic": pm.e2e(m, pm.TENET_ASIC, opt, prefill_tl=512,
                             decode_tokens=512),
    }
    cpu_lat = res["cpu"].latency_s
    for name, r in res.items():
        rows.append({"name": f"fig12/model/{name}",
                     "us_per_call": r.latency_s * 1e6,
                     "derived": f"speedup_vs_cpu={cpu_lat/r.latency_s:.1f}x;"
                                f"tok_s={r.tokens_per_s:.0f}"})
    rows.append({"name": "fig12/model/asic_vs_a100opt", "us_per_call": 0.0,
                 "derived": f"{res['a100-opt'].latency_s/res['tenet-asic'].latency_s:.2f}x"})
    return rows
