"""Fig 11 — PPL vs DAS ratio S_a and sparse-attention TL_SA (tiny scale).

Paper claim: S_a=1/2 is nearly free, S_a=1/4 (keep 8/32) degrades sharply;
TL_SA beyond ~window has marginal effect.
"""
import dataclasses
import os

from benchmarks.common import tiny_lm, train_eval_ppl
from repro.configs.base import DasConfig, LpsaConfig

STEPS = int(os.environ.get("BENCH_STEPS", "150"))


def run():
    rows = []
    for keep in (32, 16, 8):  # S_a = 1, 1/2, 1/4
        cfg = tiny_lm(f"sa{keep}")
        cfg = dataclasses.replace(cfg, ternary=dataclasses.replace(
            cfg.ternary, das=None if keep == 32 else DasConfig(32, keep)))
        r = train_eval_ppl(cfg, steps=STEPS)
        rows.append({"name": f"fig11/das_Sa={keep}/32",
                     "us_per_call": r["train_s"] * 1e6 / STEPS,
                     "derived": f"ppl={r['ppl']:.2f}"})
    for tl_sa in (16, 32, 56):
        cfg = tiny_lm(f"tl{tl_sa}")
        cfg = dataclasses.replace(cfg, lpsa=LpsaConfig(sink=8,
                                                       window=tl_sa - 8,
                                                       chunk=16))
        r = train_eval_ppl(cfg, steps=STEPS)
        rows.append({"name": f"fig11/tl_sa={tl_sa}",
                     "us_per_call": r["train_s"] * 1e6 / STEPS,
                     "derived": f"ppl={r['ppl']:.2f}"})
    return rows
