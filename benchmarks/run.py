"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  BENCH_STEPS env var scales the
training-based benches (Tables II/III, Fig 11).
"""
import sys
import time

MODULES = [
    "bench_table1_complexity",
    "bench_fig7_cores",
    "bench_fig1_ipj",
    "bench_fig12_speedup",
    "bench_fig14_breakdown",
    "bench_fig15_memory",
    "bench_table4_dse",
    "bench_fig17_sota",
    "bench_table2_accuracy",
    "bench_table3_gla",
    "bench_fig11_ablation",
    "bench_serve_engine",
]


def main() -> None:
    only = sys.argv[1:] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name in MODULES:
        if only and not any(o in name for o in only):
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        for row in mod.run():
            print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
