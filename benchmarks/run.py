"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  BENCH_STEPS env var scales the
training-based benches (Tables II/III, Fig 11).

Usage:
    PYTHONPATH=src:. python benchmarks/run.py [FILTER ...] \
        [--json BENCH.json] [--baseline benchmarks/baseline.json] \
        [--max-regression 2.0] [--history benchmarks/BENCH_history.json]

``--history`` appends this run's results as one timestamped entry (UTC time
+ git short-sha) to a JSON-list file, so per-PR CI runs accumulate a
queryable perf record alongside the pass/fail gate.

FILTER substrings select modules (e.g. ``serve_engine das_fused``).
``--json`` writes the results as {name: {us_per_call, derived}} — pointing
it at benchmarks/baseline.json is how the committed baseline is
regenerated.  ``--baseline`` compares us_per_call against a committed
baseline and exits 1 on any entry slower than ``--max-regression`` times
its baseline, OR on any baselined entry missing from the run (a renamed
bench or drifted filter must not silently void the gate).  Regressions
below a 500 µs absolute delta, baseline entries <= 0, and keys starting
with "_" are ignored: the committed baseline is wall-clock from one
machine class, so sub-millisecond entries gate only on blowups, not on
runner hardware variance.  If CI's runner class changes, refresh the
committed baseline from the uploaded BENCH.json artifact.
"""
import argparse
import datetime
import json
import subprocess
import sys
import time

MODULES = [
    "bench_table1_complexity",
    "bench_fig7_cores",
    "bench_fig1_ipj",
    "bench_fig12_speedup",
    "bench_fig14_breakdown",
    "bench_fig15_memory",
    "bench_table4_dse",
    "bench_fig17_sota",
    "bench_table2_accuracy",
    "bench_table3_gla",
    "bench_fig11_ablation",
    "bench_serve_engine",
    "bench_sharded",
    "bench_das_fused",
]

ABS_FLOOR_US = 500.0   # ignore regressions smaller than this delta


def append_history(path: str, results: dict) -> None:
    """Append one timestamped {ts, git, results} entry to a JSON-list file."""
    try:
        sha = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True).stdout.strip() \
            or None
    except OSError:
        sha = None
    try:
        with open(path) as f:
            hist = json.load(f)
        if not isinstance(hist, list):
            hist = []
    except (OSError, ValueError):
        hist = []
    hist.append({"ts": datetime.datetime.now(datetime.timezone.utc)
                 .isoformat(timespec="seconds"),
                 "git": sha, "results": results})
    with open(path, "w") as f:
        json.dump(hist, f, indent=1)


def check_regression(results: dict, baseline: dict, max_reg: float) -> list[str]:
    """-> list of human-readable violations (empty == pass)."""
    bad = []
    for name, base in baseline.items():
        if name.startswith("_"):
            continue
        base_us = base["us_per_call"] if isinstance(base, dict) else float(base)
        if name not in results:
            bad.append(f"{name}: in baseline but missing from this run "
                       f"(renamed bench or filters drifted?)")
            continue
        if base_us <= 0:
            continue
        us = results[name]["us_per_call"]
        if us > max_reg * base_us and us - base_us > ABS_FLOOR_US:
            bad.append(f"{name}: {us:.1f}us > {max_reg:.1f}x baseline "
                       f"{base_us:.1f}us")
    return bad


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("filters", nargs="*", help="module-name substrings")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write results as JSON (regenerates the baseline "
                         "when pointed at benchmarks/baseline.json)")
    ap.add_argument("--baseline", metavar="PATH", default=None,
                    help="committed baseline JSON to gate against")
    ap.add_argument("--max-regression", type=float, default=2.0)
    ap.add_argument("--history", metavar="PATH", default=None,
                    help="append a timestamped entry for this run to a "
                         "JSON-list history file")
    args = ap.parse_args()

    results: dict[str, dict] = {}
    print("name,us_per_call,derived")
    for name in MODULES:
        if args.filters and not any(o in name for o in args.filters):
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        for row in mod.run():
            print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
            results[row["name"]] = {"us_per_call": round(row["us_per_call"], 1),
                                    "derived": str(row["derived"])}
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)

    if args.json:
        payload = {"_regenerate": (
            "PYTHONPATH=src:. python benchmarks/run.py serve_engine das_fused "
            "--json benchmarks/baseline.json  # run on an idle machine; CI "
            "gates us_per_call at --max-regression 1.5")}
        payload.update(results)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)

    if args.history:
        append_history(args.history, results)
        print(f"# appended to {args.history}", file=sys.stderr)

    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        bad = check_regression(results, baseline, args.max_regression)
        for line in bad:
            print(f"# REGRESSION {line}", file=sys.stderr)
        if bad:
            sys.exit(1)
        print(f"# baseline check OK ({args.baseline})", file=sys.stderr)


if __name__ == "__main__":
    main()
