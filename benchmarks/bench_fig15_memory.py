"""Fig 15 — off-chip memory-access reduction (modeled byte accounting).

Paper: TWD cuts decode-stage access 74.8% vs int8-naive; DAS+LPSA cut
prefill access 80.3% (attention intermediates never reach DRAM).
"""
from repro.core import perfmodel as pm


def run():
    m = pm.LLAMA_3B
    rows = []
    dec_naive = pm.stage_cost(m, "decode", 2048, pm.TenetOpt.naive_int8(),
                              decode_tokens=512)
    dec_full = pm.stage_cost(m, "decode", 2048, pm.TenetOpt.full(),
                             decode_tokens=512)
    dec_red = 1 - dec_full.bytes / dec_naive.bytes
    pre_naive = pm.stage_cost(m, "prefill", 2048, pm.TenetOpt.naive_int8())
    pre_full = pm.stage_cost(m, "prefill", 2048, pm.TenetOpt.full())
    pre_red = 1 - pre_full.act_bytes / pre_naive.act_bytes
    rows.append({"name": "fig15/decode_bytes", "us_per_call": 0.0,
                 "derived": f"naive={dec_naive.bytes:.3e};tenet={dec_full.bytes:.3e};"
                            f"reduction={dec_red:.1%}"})
    rows.append({"name": "fig15/prefill_act_bytes", "us_per_call": 0.0,
                 "derived": f"naive={pre_naive.act_bytes:.3e};tenet={pre_full.act_bytes:.3e};"
                            f"reduction={pre_red:.1%}"})
    return rows
