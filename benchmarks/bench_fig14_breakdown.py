"""Fig 14 — latency breakdown across optimization levels (modeled).

TENET-ASIC naive(int8) -> +TWD -> +TWD+DAS -> +TWD+DAS+LPSA on
Sparse-BitNet-1.3B; paper: TWD cuts ~45.6% of latency, DAS+LPSA a further
~13.3%, total -40.5% vs A100-opt.
"""
from repro.core import perfmodel as pm


def run():
    m = pm.LLAMA_1B3
    variants = [
        ("naive-int8", pm.TenetOpt.naive_int8()),
        ("+twd", pm.TenetOpt.twd()),
        ("+twd+das", pm.TenetOpt.twd_das()),
        ("+twd+das+lpsa", pm.TenetOpt.full()),
    ]
    rows = []
    lat = {}
    for name, opt in variants:
        r = pm.e2e(m, pm.TENET_ASIC, opt, prefill_tl=512, decode_tokens=512)
        lat[name] = r.latency_s
        rows.append({"name": f"fig14/tenet-asic/{name}",
                     "us_per_call": r.latency_s * 1e6,
                     "derived": f"prefill_s={r.prefill_s:.4f};decode_s={r.decode_s:.4f}"})
    twd_cut = 1 - lat["+twd"] / lat["naive-int8"]
    rest_cut = 1 - lat["+twd+das+lpsa"] / lat["+twd"]
    rows.append({"name": "fig14/reductions", "us_per_call": 0.0,
                 "derived": f"twd_cut={twd_cut:.1%};das_lpsa_cut={rest_cut:.1%}"})
    return rows
