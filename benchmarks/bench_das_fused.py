"""Fused DAS->ternary GEMM serving path vs the densifying dense path.

Measures the decode-shaped packed-weight matmul on one ternary linear
(K=1280, N=512, batch=4 decode rows — slab-aligned: K = 4 x 320 trits):

  * dense_path_ref  — the pre-fusion serving path: DAS mask -> densified
    activations -> reference packed ternary GEMM (unpack + einsum; what
    serving executed on this backend before the tuned dispatch existed),
  * fused_path_tuned — the tuned serving path: the autotuner
    (kernels/autotune) picks the per-shape winner for `das_ternary_gemm`
    and the bench runs exactly what `tlin_apply(kernel_mode="tuned")`
    dispatches (on XLA-CPU: rank-compare mask + strided f32 base-3 decode
    GEMM; on TPU/GPU: a Pallas tile config),
  * gather_oracle_ref — the jnp gather oracle (tracking only; XLA-CPU
    gathers are ~15x below streaming bandwidth, which is why the tuned
    path avoids them).

All operand arrays are passed as jit ARGUMENTS: closure-captured packed
weights get constant-folded — XLA pre-decodes them at compile time and the
bench times a fiction (~8x too fast at this shape).

Wall-clock is whatever backend runs CI (the committed baseline is XLA-CPU),
so the µs columns are a *tracking* artifact for regression gating, not the
paper's TPU claim.  The bandwidth side is reported analytically in
`hbm_model`: bytes-from-HBM per token for each path (f32 activations /
compacted values + 1-byte in-block lane ids + base-3 packed weights at 1.6
bits/weight).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core import das, twd
from repro.kernels import autotune, ops, xla_gemm

M, K, N = 4, 1280, 512
BLOCK, KEEP = 32, 16
KI = 320             # interpret-mode sample kept small (one 64B slab)


def _hbm_bytes(k: int, n: int, keep: int, block: int):
    """(dense_act, fused_act, packed_w) bytes from HBM per token for one
    K x N packed layer: f32 dense activations vs f32 compacted values plus
    1-byte in-block lane ids; weights identical (base-3 packed) both ways."""
    packed = twd.packed_nbytes((k, n))
    kc = k * keep // block
    return k * 4, kc * 4 + kc * 1, packed


def run():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    trits = rng.integers(-1, 2, size=(K, N)).astype(np.int8)
    packed = jnp.asarray(twd.pack_ternary(trits))
    scale = jnp.float32(0.42)

    @jax.jit
    def dense_path(xv, p):
        m = das.das_mask(xv, block_size=BLOCK, keep=KEEP)
        xs = das.das_apply(xv, m)
        return ops.ternary_gemm(xs, p, scale, mode="ref")

    @jax.jit
    def gather_oracle(xv, p):
        ca = das.das_compact(xv, block_size=BLOCK, keep=KEEP)
        return ops.das_ternary_gemm(ca.values, ca.indices, p, scale,
                                    keep=KEEP, block=BLOCK, mode="ref")

    # eager tune (real timed runs on a cache miss), then jit the dispatch
    # exactly as tlin_apply(kernel_mode="tuned") executes it
    cfg = autotune.tune("das_ternary_gemm", m=M, k=K, n=N, keep=KEEP,
                        block=BLOCK)

    @jax.jit
    def fused_path(xv, p):
        if cfg.impl.startswith("xla_dense"):
            xs = xla_gemm.masked_dense(xv, keep=KEEP, block=BLOCK)
            return xla_gemm.decode_matmul(xs, p, scale, impl=cfg.impl)
        ca = das.das_compact(xv, block_size=BLOCK, keep=KEEP)
        return autotune.run_das_gemm(ca.values, ca.indices, p, scale,
                                     keep=KEEP, block=BLOCK, cfg=cfg)

    # parity guard so the bench can't silently time diverging paths
    want = dense_path(x, packed)
    for fn in (gather_oracle, fused_path):
        err = float(jnp.abs(want - fn(x, packed)).max())
        assert err < 1e-3, f"{fn.__name__} diverged from dense path: {err}"

    us_dense = time_fn(dense_path, x, packed)
    us_fused = time_fn(fused_path, x, packed)
    us_gather = time_fn(gather_oracle, x, packed)

    xi = x[:, :KI]
    packed_i = jnp.asarray(twd.pack_ternary(trits[:KI]))

    @jax.jit
    def fused_interpret(xv, p):
        ca = das.das_compact(xv, block_size=BLOCK, keep=KEEP)
        return ops.das_ternary_gemm(ca.values, ca.indices, p, scale,
                                    keep=KEEP, block=BLOCK, mode="interpret")

    us_interp = time_fn(fused_interpret, xi, packed_i, iters=3, warmup=1)

    d_act, f_act, w_bytes = _hbm_bytes(K, N, KEEP, BLOCK)
    d_bytes, f_bytes = d_act + w_bytes, f_act + w_bytes
    kv_dense, kv_paged = _kv_pool_bytes()
    return [
        {"name": "das_fused/dense_path_ref", "us_per_call": us_dense / M,
         "derived": f"M={M};K={K};N={N}"},
        {"name": "das_fused/fused_path_tuned", "us_per_call": us_fused / M,
         "derived": (f"vs_dense={us_fused / max(us_dense, 1e-9):.2f}x;"
                     f"impl={cfg.impl}")},
        {"name": "das_fused/gather_oracle_ref", "us_per_call": us_gather / M,
         "derived": f"vs_dense={us_gather / max(us_dense, 1e-9):.2f}x"},
        {"name": "das_fused/fused_kernel_interpret",
         "us_per_call": us_interp / M, "derived": f"M={M};K={KI};N={N}"},
        {"name": "das_fused/hbm_model", "us_per_call": 0.0,
         "derived": (f"act_ratio={f_act / d_act:.3f};"
                     f"total_ratio={f_bytes / d_bytes:.3f};"
                     f"dense_B={d_bytes};fused_B={f_bytes}")},
        {"name": "das_fused/kv_pool_model", "us_per_call": 0.0,
         "derived": (f"paged_ratio={kv_paged / kv_dense:.3f};"
                     f"dense_B={kv_dense};paged_B={kv_paged}")},
    ]


def _kv_pool_bytes(*, slots=M, max_len=64, page=8, live_tokens=96,
                   n_layers=4, hkv=2, dh=32):
    """Serving-cache side of the memory story: per-slot dense full caches
    pin slots * max_len KV rows per layer up front, while the block-paged
    pool (serve.ServeConfig(layout="paged")) pins only the pages live
    tokens touch — here the trace midpoint of the serve bench (K/V f32
    pairs + the int32 position map, per layer)."""
    row = (2 * hkv * dh * 4) + 4            # K+V f32 row + pos int32
    dense = slots * max_len * row * n_layers
    pages = -(-live_tokens // page)
    paged = pages * page * row * n_layers
    return dense, paged
