"""Fused DAS->ternary GEMM serving path vs the densifying dense path.

Measures the decode-shaped packed-weight matmul both ways on one ternary
linear (K=1280, N=512, batch=4 decode rows):

  * dense  — the pre-fusion serving path: DAS mask -> densified activations
             -> packed ternary GEMM (activations round-trip HBM dense),
  * fused  — `das_compact` -> `das_ternary_gemm` (compacted activations
             routed straight against base-3 packed weights).

Wall-clock here is XLA-on-CPU (`mode="ref"` jnp paths plus one small
interpret-mode Pallas sample), so the µs columns are a *tracking* artifact
for CI regression gating, not the paper's TPU claim.  The bandwidth side is
reported analytically in `hbm_model`: bytes-from-HBM per token for each
path (f32 activations / compacted values + 1-byte in-block lane ids +
base-3 packed weights at 1.6 bits/weight).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core import das, twd
from repro.kernels import ops

M, K, N = 4, 1280, 512
BLOCK, KEEP = 32, 16
KI = 320             # interpret-mode sample kept small (one 64B slab)


def _hbm_bytes(k: int, n: int, keep: int, block: int):
    """(dense_act, fused_act, packed_w) bytes from HBM per token for one
    K x N packed layer: f32 dense activations vs f32 compacted values plus
    1-byte in-block lane ids; weights identical (base-3 packed) both ways."""
    packed = twd.packed_nbytes((k, n))
    kc = k * keep // block
    return k * 4, kc * 4 + kc * 1, packed


def run():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    trits = rng.integers(-1, 2, size=(K, N)).astype(np.int8)
    packed = jnp.asarray(twd.pack_ternary(trits))
    scale = jnp.float32(0.42)

    @jax.jit
    def dense_path(xv):
        m = das.das_mask(xv, block_size=BLOCK, keep=KEEP)
        xs = das.das_apply(xv, m)
        return ops.ternary_gemm(xs, packed, scale, mode="ref")

    @jax.jit
    def fused_path(xv):
        ca = das.das_compact(xv, block_size=BLOCK, keep=KEEP)
        return ops.das_ternary_gemm(ca.values, ca.indices, packed, scale,
                                    keep=KEEP, block=BLOCK, mode="ref")

    # parity guard so the bench can't silently time diverging paths
    err = float(jnp.abs(dense_path(x) - fused_path(x)).max())
    assert err < 1e-3, f"fused/dense diverged: {err}"

    us_dense = time_fn(dense_path, x)
    us_fused = time_fn(fused_path, x)

    xi = x[:, :KI]
    packed_i = jnp.asarray(twd.pack_ternary(trits[:KI]))

    @jax.jit
    def fused_interpret(xv):
        ca = das.das_compact(xv, block_size=BLOCK, keep=KEEP)
        return ops.das_ternary_gemm(ca.values, ca.indices, packed_i, scale,
                                    keep=KEEP, block=BLOCK, mode="interpret")

    us_interp = time_fn(fused_interpret, xi, iters=3, warmup=1)

    d_act, f_act, w_bytes = _hbm_bytes(K, N, KEEP, BLOCK)
    d_bytes, f_bytes = d_act + w_bytes, f_act + w_bytes
    return [
        {"name": "das_fused/dense_path_ref", "us_per_call": us_dense / M,
         "derived": f"M={M};K={K};N={N}"},
        {"name": "das_fused/fused_path_ref", "us_per_call": us_fused / M,
         "derived": f"vs_dense={us_fused / max(us_dense, 1e-9):.2f}x"},
        {"name": "das_fused/fused_kernel_interpret",
         "us_per_call": us_interp / M, "derived": f"M={M};K={KI};N={N}"},
        {"name": "das_fused/hbm_model", "us_per_call": 0.0,
         "derived": (f"act_ratio={f_act / d_act:.3f};"
                     f"total_ratio={f_bytes / d_bytes:.3f};"
                     f"dense_B={d_bytes};fused_B={f_bytes}")},
    ]
