"""Table IV / Sec. IV-D — DSE grid search; paper picks P_L=16, P_H=4,
TL_SA=1024 for TENET-ASIC under L = PPL * power * latency."""
from repro.core import dse, perfmodel as pm


def run():
    rows = []
    cands = dse.dse_grid_search(pm.LLAMA_3B, "bitnet-3b")
    for i, c in enumerate(cands[:5]):
        rows.append({"name": f"table4/rank{i}", "us_per_call": c.latency_s * 1e6,
                     "derived": f"P_L={c.p_l};P_H={c.p_h};TL_SA={c.tl_sa};"
                                f"S_a={c.s_a};ppl={c.ppl:.2f};"
                                f"power_w={c.power_w:.2f};obj={c.objective:.3e}"})
    best = cands[0]
    rows.append({"name": "table4/paper_point", "us_per_call": 0.0,
                 "derived": f"best=({best.p_l},{best.p_h},{best.tl_sa});"
                            f"paper=(16,4,1024)"})
    # TPU-facing variant: pack size / TL_SA / S_a balance (DESIGN.md §2)
    tcands = dse.tpu_dse_grid_search(pm.LLAMA_3B, "bitnet-3b", pm.TPU_V5E)
    t = tcands[0]
    rows.append({"name": "table4/tpu_variant", "us_per_call": 0.0,
                 "derived": f"chunk={t['chunk']};tl_sa={t['tl_sa']};"
                            f"s_a={t['s_a']};hidden={t['hidden']}"})
    return rows
