"""TWD base-3 packing (Sec. III-E): roundtrips, density, alignment."""
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import twd


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 333), st.integers(1, 17))
def test_roundtrip_exact(seed, k, n):
    rng = np.random.default_rng(seed)
    trits = rng.integers(-1, 2, size=(k, n)).astype(np.int8)
    packed = twd.pack_ternary(trits)
    out = np.asarray(twd.unpack_ternary(jnp.asarray(packed), k))
    assert np.array_equal(out, trits)
    out2 = np.asarray(twd.unpack_ternary_arith(jnp.asarray(packed), k))
    assert np.array_equal(out2, trits)


def test_64b_80b_ratio():
    # 320 trits: 64 packed bytes vs 80 int2 bytes — the paper's block
    assert twd.packed_dim(320) == 64
    assert twd.compression_ratio_vs_int2(320) == 0.8


def test_bits_per_weight():
    k = 10_000
    bits = twd.packed_dim(k) * 8 / k
    assert 1.58 <= bits <= 1.62


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 2000))
def test_row_align(seed, k):
    rng = np.random.default_rng(seed)
    trits = rng.integers(-1, 2, size=(k, 4)).astype(np.int8)
    packed = twd.pack_ternary(trits, row_align=16)
    assert packed.shape[0] % 16 == 0
    out = np.asarray(twd.unpack_ternary(jnp.asarray(packed), k))
    assert np.array_equal(out, trits)


def test_invalid_bytes_decode_to_zero():
    bad = jnp.full((2, 3), 250, jnp.uint8)  # >= 243: invalid encodings
    out = np.asarray(twd.unpack_ternary(bad, 10))
    assert np.all(out == 0)


def test_decode_lut_matches_arith(rng):
    packed = jnp.asarray(rng.integers(0, 243, size=(40, 8)), jnp.uint8)
    a = np.asarray(twd.unpack_ternary(packed, 200))
    b = np.asarray(twd.unpack_ternary_arith(packed, 200))
    assert np.array_equal(a, b)
