"""MoE routing/dispatch: capacity-bounded sort dispatch == dense loop."""
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import moe as MOE


def _dense_reference(p, cfg, x):
    """Loop-over-experts oracle (no capacity drops)."""
    e = cfg.moe
    t = x.shape[0] * x.shape[1]
    xt = x.reshape(t, -1)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, e.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    wg, wi, wo = MOE._expert_weights(p, cfg, xt.dtype)
    from repro.core import ternary as tq
    xin = tq.int8_fake_quant(xt) if cfg.ternary.enabled else xt
    y = jnp.zeros_like(xt)
    for k in range(e.top_k):
        for ei in range(e.n_experts):
            sel = (expert[:, k] == ei)
            h = jax.nn.silu(xin @ wg[ei]) * (xin @ wi[ei])
            ye = h @ wo[ei]
            y = y + jnp.where(sel[:, None], ye * gate[:, k:k+1], 0.0)
    return y.reshape(x.shape)


def test_dispatch_matches_dense_loop():
    cfg = reduced(get_config("qwen3-moe-30b-a3b"))
    cfg = dataclasses.replace(cfg, ternary=dataclasses.replace(
        cfg.ternary, das=None))
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    got = MOE.moe_apply(p, cfg, x)
    want = _dense_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_tokens():
    cfg = reduced(get_config("qwen3-moe-30b-a3b"))
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=0.05))
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y = MOE.moe_apply(p, cfg, x)
    assert bool(jnp.isfinite(y).all())
    # with tiny capacity most tokens drop -> much smaller output norm
    cfg_full = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=4.0))
    y_full = MOE.moe_apply(p, cfg_full, x)
    assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(y_full))


def test_shared_expert_added():
    cfg = reduced(get_config("kimi-k2-1t-a32b"))
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    assert "shared_gate" in p
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    y = MOE.moe_apply(p, cfg, x)
    assert bool(jnp.isfinite(y).all())
