"""SLO-aware scheduling: aging, deadlines, preemption, idle fast-forward.

The long-running-server bug this pins: the old FifoScheduler ordered
strictly by (priority, arrival), so a saturating stream of priority-0
requests starved priority-1 forever.  Aging makes effective priority
decay with queue wait (a static heap key — see serve/scheduler.py), and
the DeadlineScheduler builds earliest-effective-deadline-first admission
on top of it; the engine's preemption hook truncates over-budget slots to
rescue deadline-critical arrivals.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import DasConfig, LpsaConfig, ModelConfig, TernaryConfig
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.serve import (DeadlineScheduler, FifoScheduler, Request,
                         ServeConfig, ServeEngine)

CFG = ModelConfig(
    name="tiny-slo", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
    ternary=TernaryConfig(das=DasConfig(16, 8)),
    lpsa=LpsaConfig(sink=4, window=12, chunk=8),
    dtype="float32", remat=False, scan_layers=False,
)


@pytest.fixture(scope="module")
def sparams():
    params = MD.init_params(jax.random.PRNGKey(0), CFG)
    return MD.export_serving(params, CFG)


def mk(uid, arr, pri=0, slo=None, gen=1, plen=1):
    return Request(uid=uid, prompt=np.zeros(plen, np.int32),
                   max_new_tokens=gen, arrival=arr, priority=pri,
                   slo_steps=slo)


# -------------------------------------------------------------------------
# aging: a saturating high-priority stream cannot starve low priority
# -------------------------------------------------------------------------

def test_fifo_aging_prevents_starvation():
    s = FifoScheduler(aging_steps=8)
    s.add(mk(999, 0, pri=1))           # the low-priority victim
    uid, now, popped = 0, 0, []
    # one fresh priority-0 arrival per tick, one admission per tick
    for now in range(64):
        s.add(mk(uid, now, pri=0))
        uid += 1
        popped.append(s.pop_ready(now).uid)
        if 999 in popped:
            break
    assert 999 in popped, "aged low-priority request never admitted"
    # it overtakes after waiting ~ aging_steps * (priority gap)
    assert popped.index(999) <= 2 * 8


def test_fifo_aging_zero_is_strict_priority():
    """aging_steps=0 restores the legacy starvation-prone order (the bug,
    kept reachable as an explicit opt-out)."""
    s = FifoScheduler(aging_steps=0)
    s.add(mk(999, 0, pri=1))
    for now in range(200):
        s.add(mk(now, now, pri=0))
        assert s.pop_ready(now).uid != 999   # starved forever


def test_fifo_same_priority_stays_arrival_ordered():
    s = FifoScheduler(aging_steps=8)
    for uid, arr in ((0, 3), (1, 1), (2, 2)):
        s.add(mk(uid, arr))
    assert [s.pop_ready(10).uid for _ in range(3)] == [1, 2, 0]


# -------------------------------------------------------------------------
# deadline scheduler: EDF over slo_steps with aged defaults
# -------------------------------------------------------------------------

def test_deadline_orders_by_effective_deadline():
    s = DeadlineScheduler(aging_steps=8, default_slo=100)
    s.add(mk(0, 0, slo=50))
    s.add(mk(1, 0, slo=10))       # tightest deadline first
    s.add(mk(2, 0))               # no SLO -> default budget (latest)
    s.add(mk(3, 5, slo=2))        # later arrival but deadline 7 < 10
    assert [s.pop_ready(5).uid for _ in range(4)] == [3, 1, 0, 2]


def test_deadline_no_slo_low_priority_not_starved():
    s = DeadlineScheduler(aging_steps=4, default_slo=16)
    s.add(mk(999, 0, pri=2))      # deadline 0 + 16 + 2*4 = 24
    for now in range(64):
        s.add(mk(now, now, slo=20))   # fresh deadline now + 20
        if s.pop_ready(now).uid == 999:
            break
    else:
        pytest.fail("no-SLO low-priority request starved under EDF")


def test_peek_ready_does_not_remove():
    s = DeadlineScheduler()
    s.add(mk(0, 0, slo=10))
    assert s.peek_ready(0).uid == 0
    assert s.peek_ready(0).uid == 0
    assert s.pop_ready(0).uid == 0
    assert s.peek_ready(0) is None


# -------------------------------------------------------------------------
# next_arrival: O(1), exact when it matters
# -------------------------------------------------------------------------

def test_next_arrival_deep_ready_queue():
    """The old implementation rescanned every ready entry per idle tick;
    now a tracked bound answers in O(1).  Semantics: exact whenever
    nothing is admissible (the only case that moves the clock), and a
    lower bound <= the clock otherwise (so fast-forward is a no-op)."""
    s = FifoScheduler(aging_steps=8)
    for uid in range(5000):
        s.add(mk(uid, uid % 7))       # all admissible at now=7
    s._migrate(7)
    assert len(s._ready) == 5000
    assert s.next_arrival() <= 7      # bound never moves the clock past now
    # drain: bound stays a valid lower bound throughout
    for _ in range(5000):
        nxt = s.next_arrival()
        assert nxt is not None and nxt <= 7
        s.pop_ready(7)
    assert s.next_arrival() is None
    # future-only: exact head (this is what idle fast-forward uses)
    s.add(mk(0, 42))
    assert s.next_arrival() == 42


def test_engine_idle_fast_forward_far_future(sparams):
    """An idle engine jumps the virtual clock to the next arrival instead
    of ticking through the gap."""
    eng = ServeEngine(CFG, sparams, Runtime(),
                      config=ServeConfig(max_slots=2, max_len=64))
    eng.submit(mk(0, 10_000, gen=2, plen=4))
    res = eng.run()
    assert res[0].admit_vtime >= 10_000
    assert eng.stats.decode_steps < 20   # no per-step crawl across the gap


def test_engine_empty_run(sparams):
    eng = ServeEngine(CFG, sparams, Runtime(),
                      config=ServeConfig(max_slots=2, max_len=64))
    assert eng.run() == {}
    assert eng.stats.decode_steps == 0


def test_bench_summarize_empty_trace(sparams):
    """bench_serve_engine._summarize must not call np.percentile on an
    empty array when a trace yields no results."""
    bench = pytest.importorskip("benchmarks.bench_serve_engine")
    eng = ServeEngine(CFG, sparams, Runtime(),
                      config=ServeConfig(max_slots=2, max_len=64))
    row = bench._summarize(eng, {})
    assert row["p50"] == 0.0 and row["p95"] == 0.0
    assert bench._attainment({}) == 0.0


# -------------------------------------------------------------------------
# engine integration: SLO admission + preemption rescue
# -------------------------------------------------------------------------

def _prompt(rng, n):
    return np.asarray(rng.integers(0, CFG.vocab, n), np.int32)


def test_deadline_admission_beats_fifo_on_burst(sparams):
    """A tight-SLO request landing behind a burst of loose-SLO work is
    admitted earlier under deadline scheduling."""
    rng = np.random.default_rng(0)
    trace = [Request(uid=i, prompt=_prompt(rng, 12), max_new_tokens=10,
                     arrival=0, slo_steps=200) for i in range(4)]
    trace.append(Request(uid=9, prompt=_prompt(rng, 4), max_new_tokens=2,
                         arrival=1, slo_steps=12))
    admits = {}
    for sched in ("fifo", "deadline"):
        eng = ServeEngine(CFG, sparams, Runtime(),
                          config=ServeConfig(max_slots=2, max_len=64,
                                             scheduler=sched))
        for r in trace:
            eng.submit(r)
        admits[sched] = eng.run()[9].admit_vtime
    assert admits["deadline"] <= admits["fifo"]


def test_preemption_rescues_deadline_critical(sparams):
    """One slot, blocked by a request that already blew its own SLO: with
    preemption the blocker is truncated (preempted=True, fewer tokens)
    and the critical request meets its deadline; without preemption it
    misses."""
    rng = np.random.default_rng(1)
    blocker = Request(uid=0, prompt=_prompt(rng, 4), max_new_tokens=40,
                      arrival=0, slo_steps=5)     # will be over budget fast
    critical = Request(uid=1, prompt=_prompt(rng, 4), max_new_tokens=2,
                       arrival=8, slo_steps=10)

    def run(preempt):
        eng = ServeEngine(CFG, sparams, Runtime(),
                          config=ServeConfig(max_slots=1, max_len=64,
                                             scheduler="deadline",
                                             preemption=preempt))
        eng.submit(blocker)
        eng.submit(critical)
        return eng, eng.run()

    eng_off, res_off = run(False)
    assert eng_off.stats.preemptions == 0
    assert not res_off[1].slo_met                  # starved behind blocker
    assert len(res_off[0].tokens) == 40

    eng_on, res_on = run(True)
    assert eng_on.stats.preemptions == 1
    assert res_on[0].preempted and not res_on[0].slo_met
    assert 0 < len(res_on[0].tokens) < 40          # truncated, not dropped
    assert res_on[1].slo_met and not res_on[1].preempted
    assert len(res_on[1].tokens) == 2
    # the preempted request's tokens are a prefix of its un-preempted run
    np.testing.assert_array_equal(
        res_on[0].tokens, res_off[0].tokens[:len(res_on[0].tokens)])


def test_preemption_never_touches_requests_within_budget(sparams):
    """A slot still inside its own SLO budget is not preemptible even when
    the queue head is critical."""
    rng = np.random.default_rng(2)
    eng = ServeEngine(CFG, sparams, Runtime(),
                      config=ServeConfig(max_slots=1, max_len=64,
                                         scheduler="deadline",
                                         preemption=True))
    eng.submit(Request(uid=0, prompt=_prompt(rng, 4), max_new_tokens=10,
                       arrival=0, slo_steps=300))   # generous budget
    eng.submit(Request(uid=1, prompt=_prompt(rng, 4), max_new_tokens=2,
                       arrival=1, slo_steps=3))     # hopeless deadline
    res = eng.run()
    assert eng.stats.preemptions == 0
    assert not res[0].preempted and len(res[0].tokens) == 10


def test_serve_config_validates_scheduler_fields():
    with pytest.raises(ValueError, match="unknown scheduler"):
        ServeConfig(scheduler="lifo")
    with pytest.raises(ValueError, match="aging_steps"):
        ServeConfig(aging_steps=-1)
    with pytest.raises(ValueError, match="slo_default_steps"):
        ServeConfig(slo_default_steps=0)
    with pytest.raises(ValueError, match="preemption requires"):
        ServeConfig(preemption=True)   # scheduler defaults to fifo
    ServeConfig(scheduler="deadline", preemption=True)   # valid
