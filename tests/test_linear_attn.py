"""Chunked linear attention (GLA/RWKV engine) + Mamba2 SSD recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.linear_attn import chunked_linear_attn, linear_attn_step


@pytest.mark.parametrize("mode", ["gla", "rwkv"])
@pytest.mark.parametrize("chunk", [4, 8, 16, 64])
def test_chunked_matches_step(mode, chunk):
    B, L, H, D = 2, 32, 3, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, L, H, D))
    k = jax.random.normal(ks[1], (B, L, H, D))
    v = jax.random.normal(ks[2], (B, L, H, D))
    la = -jax.nn.softplus(jax.random.normal(ks[3], (B, L, H, D))) * 0.3
    u = jax.random.normal(ks[4], (H, D)) * 0.1 if mode == "rwkv" else None

    o_chunk, s_fin = chunked_linear_attn(q, k, v, la, chunk=chunk, mode=mode,
                                         u=u)
    s = jnp.zeros((B, H, D, D))
    outs = []
    for t in range(L):
        o, s = linear_attn_step(q[:, t], k[:, t], v[:, t], la[:, t], s,
                                mode=mode, u=u)
        outs.append(o)
    o_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(o_chunk), np.asarray(o_step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(s),
                               rtol=2e-4, atol=2e-4)


def test_strong_decay_no_overflow():
    """Decays at the clamp boundary must stay finite (f32)."""
    B, L, H, D = 1, 64, 2, 8
    q = jnp.ones((B, L, H, D))
    k = jnp.ones((B, L, H, D))
    v = jnp.ones((B, L, H, D))
    la = jnp.full((B, L, H, D), -50.0)  # far below LOG_A_MIN
    o, s = chunked_linear_attn(q, k, v, la, chunk=64, mode="gla")
    assert np.isfinite(np.asarray(o)).all()
    assert np.isfinite(np.asarray(s)).all()


def test_mamba_seq_matches_decode():
    from repro.configs import get_config, reduced
    from repro.models import mamba2 as M
    from repro.models import kvcache as KV
    cfg = reduced(get_config("zamba2-2.7b"))
    p = M.mamba_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 2 * cfg.ssm.chunk   # full chunks: decode folds state at S-1
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    y_seq, (s_fin, _) = M.mamba_train(p, cfg, x)
    st = KV.init_cache(cfg, KV.CacheSpec("mamba", B))
    ys = []
    for t in range(S):
        y, st = M.mamba_decode(p, cfg, x[:, t:t+1], st, jnp.array(t))
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    # chunk-replay decode recomputes the prefill grid: single-op noise only
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_dec),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(st["ssm"]),
                               rtol=2e-5, atol=2e-5)


def test_mamba_prefill_state_handoff():
    """Prefill at a non-boundary length hands decode the boundary state +
    buffered remainder; decode continues on the same chunk grid."""
    from repro.configs import get_config, reduced
    from repro.models import mamba2 as M
    cfg = reduced(get_config("zamba2-2.7b"))
    p = M.mamba_init(jax.random.PRNGKey(0), cfg)
    c = cfg.ssm.chunk
    B, S = 2, 2 * c
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    y_seq, _ = M.mamba_train(p, cfg, x)
    for pre in (c // 2, c, c + c // 2):   # below / at / past a boundary
        y_pre, st = M.mamba_train(p, cfg, x[:, :pre], return_state=True)
        ys = [y_pre]
        for t in range(pre, S):
            y, st = M.mamba_decode(p, cfg, x[:, t:t+1], st, jnp.array(t))
            ys.append(y)
        y_dec = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_dec),
                                   rtol=2e-4, atol=2e-4, err_msg=f"pre={pre}")
