"""Front-door tests: telemetry parity, HTTP end-to-end, and the soak test
pinning the long-running-server bugfix (bounded ``_results`` + uid reuse).

The HTTP tests drive the real ``ServeHTTPServer`` on an ephemeral port
with raw asyncio stream clients (the server speaks plain HTTP/1.1 with
``Connection: close``, so one read-to-EOF captures unary and SSE bodies
alike).
"""
import asyncio
import json

import jax
import numpy as np
import pytest

from repro.configs.base import DasConfig, LpsaConfig, ModelConfig, TernaryConfig
from repro.kernels import ops
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.serve import Request, ServeConfig, ServeEngine, Telemetry
from repro.serve.server import ServeHTTPServer

CFG = ModelConfig(
    name="tiny-http", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
    ternary=TernaryConfig(das=DasConfig(16, 8)),
    lpsa=LpsaConfig(sink=4, window=12, chunk=8),
    dtype="float32", remat=False, scan_layers=False,
)


@pytest.fixture(scope="module")
def sparams():
    params = MD.init_params(jax.random.PRNGKey(0), CFG)
    return MD.export_serving(params, CFG)


def _engine(sparams, **kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_len", 64)
    return ServeEngine(CFG, sparams, Runtime(), config=ServeConfig(**kw))


def _req(uid, plen=4, gen=3, arrival=0, slo=None, rng=None):
    rng = rng or np.random.default_rng(uid)
    return Request(uid=uid,
                   prompt=np.asarray(rng.integers(0, CFG.vocab, plen),
                                     np.int32),
                   max_new_tokens=gen, arrival=arrival, slo_steps=slo)


# =========================================================================
# telemetry parity with EngineStats
# =========================================================================

def test_telemetry_matches_engine_stats(sparams, tmp_path):
    path = tmp_path / "metrics.jsonl"
    eng = _engine(sparams, scheduler="deadline")
    tele = Telemetry(engine=eng, jsonl_path=str(path), snapshot_every=4)
    for i in range(5):
        eng.submit(_req(i, slo=200 if i % 2 else None))
    res = eng.run()
    assert len(res) == 5

    assert tele.tokens_out == eng.stats.generated_tokens
    assert tele.requests_finished == 5
    assert tele.preemptions == eng.stats.preemptions == 0
    assert tele.slo_tracked == 2 and tele.slo_met == 2
    assert tele.queue_wait_steps == sum(r.queue_wait_steps
                                        for r in res.values())

    snap = tele.snapshot(eng)
    assert snap["totals"]["tokens_out"] == eng.stats.generated_tokens
    assert snap["slo_attainment"] == 1.0
    assert snap["engine"]["decode_steps"] == eng.stats.decode_steps
    assert snap["engine"]["kernel_fallbacks"] == eng.kernel_fallback_deltas()
    assert snap["pool"]["layout"] in ("paged", "dense")
    assert 0.0 < snap["rolling"]["slot_utilization"] <= 1.0

    tele.close()
    lines = [json.loads(x) for x in path.read_text().splitlines()]
    reqs = [x for x in lines if x["type"] == "request"]
    ticks = [x for x in lines if x["type"] == "tick"]
    assert len(reqs) == 5
    assert ticks, "expected periodic tick snapshots"
    assert sum(x["new_tokens"] for x in reqs) == eng.stats.generated_tokens
    assert all(x["slo_met"] for x in reqs if x["slo_steps"] is not None)


def test_kernel_fallback_deltas_are_per_engine(sparams):
    """satellite bugfix: stats.kernel_fallbacks used to snapshot the
    process-wide counter, so an engine inherited every fallback any other
    engine (or test) had ever recorded."""
    ops.note_fallback("das_matmul", ("x",), "pre-existing noise")
    eng_a = _engine(sparams)
    ops.note_fallback("lpsa_attn", ("y",), "between constructions")
    eng_b = _engine(sparams)

    assert "lpsa_attn" in " ".join(eng_a.kernel_fallback_deltas())
    assert eng_b.kernel_fallback_deltas() == {}
    # reset_clock re-baselines: eng_a forgets the old noise too
    eng_a.reset_clock()
    assert eng_a.kernel_fallback_deltas() == {}


# =========================================================================
# pop_result / drain_results: bounded memory + uid reuse (satellite bugfix)
# =========================================================================

def test_pop_result_allows_uid_reuse(sparams):
    eng = _engine(sparams)
    eng.submit(_req(7))
    eng.run_forever()            # drain-and-return; results NOT drained
    assert 7 in eng._results

    with pytest.raises(ValueError, match="unclaimed result"):
        eng.submit(_req(7))      # old bug: permanent uid exhaustion

    first = eng.pop_result(7)
    assert first is not None and len(first.tokens) == 3
    assert eng.pop_result(7) is None          # single-claim
    assert eng._results == {}

    eng.submit(_req(7))                        # same uid, accepted again
    res = eng.run()
    assert res[7].admit_vtime > first.admit_vtime


def test_drain_results_empties_store(sparams):
    eng = _engine(sparams)
    for i in range(3):
        eng.submit(_req(i))
    eng.run_forever()
    out = eng.drain_results()
    assert sorted(out) == [0, 1, 2]
    assert eng.drain_results() == {}
    for i in range(3):
        eng.submit(_req(i))                    # all uids reusable


def test_soak_bounded_results_and_uid_cycling(sparams):
    """10k sequential requests through run_forever with incremental
    pop_result keep len(_results) bounded while uids cycle through a tiny
    space, and telemetry deltas match EngineStats — the long-running
    server can actually run long."""
    N, UIDS = 10_000, 16
    eng = _engine(sparams, max_slots=8)
    tele = Telemetry(engine=eng)
    rng = np.random.default_rng(0)
    state = {"submitted": 0, "inflight": set(), "finished": 0,
             "max_results": 0}

    def on_finish(result):
        claimed = eng.pop_result(result.uid)
        assert claimed is not None and claimed.uid == result.uid
        state["inflight"].discard(result.uid)
        state["finished"] += 1

    eng.on_finish = on_finish

    def poll():
        while state["submitted"] < N:
            uid = state["submitted"] % UIDS
            if uid in state["inflight"]:
                return
            eng.submit(Request(
                uid=uid,
                prompt=np.asarray(rng.integers(0, CFG.vocab,
                                               int(rng.integers(3, 6))),
                                  np.int32),
                max_new_tokens=2, arrival=eng.vtime))
            state["inflight"].add(uid)
            state["submitted"] += 1
            state["max_results"] = max(state["max_results"],
                                       len(eng._results))

    eng.run_forever(poll=poll)

    assert state["submitted"] == N
    assert state["finished"] == N
    assert eng._results == {}, "results leaked past pop_result"
    assert state["max_results"] <= UIDS
    # telemetry kept pace with the authoritative engine counters
    assert tele.requests_finished == N
    assert tele.tokens_out == eng.stats.generated_tokens == 2 * N


# =========================================================================
# HTTP end-to-end
# =========================================================================

async def _http(port, method, path, body=None):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    payload = json.dumps(body).encode() if body is not None else b""
    head = (f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(payload)}\r\n\r\n")
    writer.write(head.encode() + payload)
    await writer.drain()
    raw = await asyncio.wait_for(reader.read(), timeout=60)
    writer.close()
    head_raw, _, body_raw = raw.partition(b"\r\n\r\n")
    lines = head_raw.decode("latin-1").split("\r\n")
    status = int(lines[0].split(" ")[1])
    headers = {}
    for ln in lines[1:]:
        if ":" in ln:
            k, v = ln.split(":", 1)
            headers[k.strip().lower()] = v.strip()
    return status, headers, body_raw


def _run(coro):
    return asyncio.run(asyncio.wait_for(coro, timeout=120))


def test_http_end_to_end(sparams):
    eng = _engine(sparams, scheduler="deadline")
    srv = ServeHTTPServer(eng, port=0, max_queue_depth=8,
                          default_slo_steps=100)

    async def scenario():
        await srv.start()
        p = srv.port
        assert p != 0

        # unary completion
        st, hdr, body = await _http(p, "POST", "/v1/completions",
                                    {"prompt": [1, 2, 3, 4],
                                     "max_tokens": 4})
        assert st == 200
        out = json.loads(body)
        assert out["object"] == "text_completion"
        assert len(out["choices"][0]["token_ids"]) == 4
        assert out["usage"]["prompt_tokens"] == 4
        assert out["usage"]["completion_tokens"] == 4
        assert out["usage"]["slo_met"] is True      # default_slo_steps

        # string prompt convenience (byte-tokenized)
        st, _, body = await _http(p, "POST", "/v1/completions",
                                  {"prompt": "hello", "max_tokens": 2})
        assert st == 200
        assert len(json.loads(body)["choices"][0]["token_ids"]) == 2

        # SSE streaming: one chunk per token, final usage chunk, [DONE]
        st, hdr, body = await _http(p, "POST", "/v1/completions",
                                    {"prompt": [5, 6, 7], "max_tokens": 3,
                                     "stream": True, "slo_steps": 200})
        assert st == 200
        assert hdr["content-type"] == "text/event-stream"
        events = [ln[len("data: "):] for ln in body.decode().split("\n\n")
                  if ln.startswith("data: ")]
        assert events[-1] == "[DONE]"
        chunks = [json.loads(e) for e in events[:-1]]
        assert all(c["object"] == "text_completion.chunk" for c in chunks)
        token_chunks = [c for c in chunks if c["choices"][0]["token_ids"]]
        assert len(token_chunks) == 3
        final = chunks[-1]
        assert final["choices"][0]["finish_reason"] == "stop"
        assert final["usage"]["completion_tokens"] == 3
        assert final["usage"]["slo_met"] is True

        # /metrics reflects the three finished requests
        st, _, body = await _http(p, "GET", "/metrics")
        assert st == 200
        snap = json.loads(body)
        assert snap["totals"]["requests_finished"] == 3
        assert snap["totals"]["tokens_out"] == 9
        assert snap["engine"]["active_slots"] == 0
        assert "pages_in_use" in snap["pool"]

        # /healthz
        st, _, body = await _http(p, "GET", "/healthz")
        assert st == 200 and json.loads(body)["ok"] is True

        # malformed inputs -> 400 with an error message
        for bad in ({"prompt": []}, {"prompt": ""}, {"prompt": 42},
                    {"prompt": [999999]}, {"prompt": [1], "max_tokens": -1},
                    {"prompt": [1], "max_tokens": "lots"}):
            st, _, body = await _http(p, "POST", "/v1/completions", bad)
            assert st == 400, bad
            assert "message" in json.loads(body)["error"]
        st, _, _ = await _http(p, "GET", "/nope")
        assert st == 404

        await srv.stop()
        assert not srv._thread.is_alive(), "engine thread not joined"
        # results were popped as they finished: nothing leaked
        assert eng._results == {}

    _run(scenario())


def test_http_backpressure_429(sparams):
    eng = _engine(sparams)
    srv = ServeHTTPServer(eng, port=0, max_queue_depth=0)  # always full

    async def scenario():
        await srv.start()
        st, hdr, body = await _http(srv.port, "POST", "/v1/completions",
                                    {"prompt": [1, 2], "max_tokens": 1})
        assert st == 429
        assert hdr.get("retry-after") == "1"
        assert "capacity" in json.loads(body)["error"]["message"]
        await srv.stop()

    _run(scenario())


def test_http_concurrent_streams(sparams):
    """several clients in flight at once: every stream completes and the
    engine batches them (telemetry sees overlapping slots)."""
    eng = _engine(sparams, max_slots=4, scheduler="deadline")
    srv = ServeHTTPServer(eng, port=0, max_queue_depth=16)

    async def one(i):
        st, _, body = await _http(srv.port, "POST", "/v1/completions",
                                  {"prompt": [i + 1, i + 2, i + 3],
                                   "max_tokens": 4, "stream": True})
        assert st == 200
        assert body.rstrip().endswith(b"data: [DONE]")

    async def scenario():
        await srv.start()
        await asyncio.gather(*(one(i) for i in range(6)))
        st, _, body = await _http(srv.port, "GET", "/metrics")
        assert json.loads(body)["totals"]["requests_finished"] == 6
        await srv.stop()
        assert eng._results == {}

    _run(scenario())
