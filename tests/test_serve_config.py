"""ServeConfig validation + the KernelMode enum + the legacy-kwarg shim."""
import jax
import numpy as np
import pytest

from repro.configs.base import DasConfig, ModelConfig, TernaryConfig
from repro.kernels.ops import KERNEL_MODES, KernelMode
from repro.models import model as MD
from repro.models.ternary_linear import tlin_apply, tlin_init
from repro.models.transformer import Runtime
from repro.serve import Request, ServeConfig, ServeEngine

CFG = ModelConfig(
    name="tiny-cfg", family="dense", n_layers=1, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
    ternary=TernaryConfig(das=DasConfig(16, 8)),
    dtype="float32", remat=False, scan_layers=False,
)


@pytest.fixture(scope="module")
def sparams():
    params = MD.init_params(jax.random.PRNGKey(0), CFG)
    return MD.export_serving(params, CFG)


# -------------------------------------------------------------------------
# KernelMode
# -------------------------------------------------------------------------

def test_kernel_mode_parse_members_and_aliases():
    assert KernelMode.parse("ref") is KernelMode.REF
    assert KernelMode.parse(KernelMode.TUNED) is KernelMode.TUNED
    # aliases map onto canonical modes
    assert KernelMode.parse("reference") is KernelMode.REF
    assert KernelMode.parse("xla") is KernelMode.REF
    assert KernelMode.parse("interp") is KernelMode.INTERPRET
    assert KernelMode.parse("mosaic") is KernelMode.PALLAS
    assert KernelMode.parse("autotune") is KernelMode.TUNED
    # the enum doubles as its string (str mixin)
    assert KernelMode.COMPILED == "compiled"
    assert str(KernelMode.AUTO) == "auto"
    assert KERNEL_MODES == ("ref", "interpret", "pallas", "compiled",
                            "tuned", "auto", "sharded")
    assert KernelMode.parse("spmd") is KernelMode.SHARDED
    assert KernelMode.parse("gspmd") is KernelMode.SHARDED


def test_kernel_mode_unknown_lists_valid_modes():
    with pytest.raises(ValueError) as ei:
        KernelMode.parse("warp9")
    msg = str(ei.value)
    for m in KERNEL_MODES:
        assert m in msg


def test_tlin_apply_accepts_aliases_rejects_junk(rng):
    p = tlin_init(jax.random.PRNGKey(1), 64, 64, np.float32)
    x = np.asarray(rng.standard_normal((2, 64)), np.float32)
    a = tlin_apply(p, x, CFG.ternary, kernel_mode="ref")
    b = tlin_apply(p, x, CFG.ternary, kernel_mode="reference")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="kernel mode"):
        tlin_apply(p, x, CFG.ternary, kernel_mode="warp9")


# -------------------------------------------------------------------------
# ServeConfig
# -------------------------------------------------------------------------

def test_serve_config_defaults_and_validation():
    sc = ServeConfig()
    assert sc.max_slots == 4 and sc.layout == "auto" and sc.policy == \
        "continuous"
    assert sc.pages_per_seq == 0 and sc.resolved_num_pages() == 0
    pc = ServeConfig(max_slots=2, max_len=64, layout="paged", page_size=16)
    assert pc.pages_per_seq == 4
    assert pc.resolved_num_pages() == 2 * 4 + 1     # worst case + null page
    with pytest.raises(ValueError):
        ServeConfig(max_slots=0)
    with pytest.raises(ValueError):
        ServeConfig(policy="banana")
    with pytest.raises(ValueError):
        ServeConfig(layout="banana")
    with pytest.raises(ValueError):                  # max_len % page_size
        ServeConfig(layout="paged", max_len=50, page_size=16)
    with pytest.raises(ValueError):                  # num_pages = 1
        ServeConfig(layout="paged", max_len=64, page_size=16, num_pages=1)
    with pytest.raises(ValueError):                  # bad kernel mode
        ServeConfig(kernel_mode="warp9")
    assert ServeConfig(kernel_mode="reference").kernel_mode == "ref"


def test_serve_config_with_updates():
    sc = ServeConfig().with_updates(max_slots=8, top_k=5)
    assert sc.max_slots == 8 and sc.top_k == 5
    with pytest.raises(TypeError, match="unknown"):
        ServeConfig().with_updates(max_slotz=8)


# -------------------------------------------------------------------------
# the legacy-kwarg shim on ServeEngine
# -------------------------------------------------------------------------

def _run_one(eng):
    eng.submit(Request(uid=0,
                       prompt=np.arange(7, dtype=np.int32) % 256,
                       max_new_tokens=5, temperature=0.7, arrival=0))
    return eng.run()[0].tokens


def test_legacy_kwargs_warn_and_match_config(sparams):
    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy = ServeEngine(CFG, sparams, Runtime(), max_slots=1, max_len=32)
    assert legacy.config == ServeConfig(max_slots=1, max_len=32)
    modern = ServeEngine(CFG, sparams, Runtime(),
                         config=ServeConfig(max_slots=1, max_len=32))
    np.testing.assert_array_equal(_run_one(legacy), _run_one(modern))


def test_unknown_engine_kwarg_is_typeerror(sparams):
    with pytest.raises(TypeError, match="unknown ServeEngine kwarg"):
        ServeEngine(CFG, sparams, Runtime(), max_slotz=1)
