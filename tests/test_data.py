"""Data pipeline: sharding, prefetch, file-backed source."""
import numpy as np

from repro.data.pipeline import FileTokens, Prefetcher, SyntheticLM


def test_shards_partition_batch():
    full = SyntheticLM(vocab=64, seq_len=8, batch=8, seed=1)
    sh0 = SyntheticLM(vocab=64, seq_len=8, batch=8, seed=1, shard=0, n_shards=2)
    assert sh0.batch_at(0)["inputs"].shape == (4, 8)


def test_labels_are_shifted_inputs():
    src = SyntheticLM(vocab=64, seq_len=8, batch=2, seed=1)
    b = src.batch_at(0)
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])


def test_prefetcher_orders_batches():
    src = SyntheticLM(vocab=64, seq_len=8, batch=2, seed=1)
    pf = Prefetcher(src, start_step=0, depth=2)
    steps = [next(pf)[0] for _ in range(4)]
    pf.stop()
    assert steps == [0, 1, 2, 3]


def test_file_tokens(tmp_path):
    path = str(tmp_path / "toks.bin")
    data = (np.arange(1000) % 251).astype(np.uint16)
    data.tofile(path)
    src = FileTokens(path=path, vocab=251, seq_len=9, batch=4)
    b = src.batch_at(0)
    assert b["inputs"].shape == (4, 9)
    assert b["inputs"].max() < 251
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])
