"""Block-paged KV pool: cache factory, radix trie, and engine semantics.

(a) CacheSpec/init_cache factory (incl. the deprecated init_attn_* shims);
(b) paged attn_write/attn_read against the dense full layout at the cache
    layer;
(c) kvpool unit behaviour: PagePool refcounts and RadixIndex lookup;
(d) engine integration: bitwise token parity paged-vs-per-slot on a
    staggered trace with a duplicate prompt (exact prefix hit on the way);
(e) prefix sharing prefills strictly fewer prompt tokens;
(f) refcount/copy-on-write correctness under interleaved retire+admit;
(g) used pool memory tracks live tokens, not max_slots * max_len.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (DasConfig, LpsaConfig, ModelConfig,
                                SsmConfig, TernaryConfig)
from repro.models import kvcache as KV
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.serve import Request, ServeConfig, ServeEngine
from repro.serve.kvpool import PagePool, PrefixEntry, RadixIndex

# two layer mixes: attn-only (every layer becomes a page arena -> page-donor
# sharing legal) and attn+local (ring layers ride along per-slot -> only
# exact snapshot reuse).  serve_sparse=False keeps "attn" layers full-cache.
CFG_FULL = ModelConfig(
    name="tiny-paged", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
    ternary=TernaryConfig(das=DasConfig(16, 8)),
    dtype="float32", remat=False, scan_layers=False,
)
CFG_MIXED = ModelConfig(
    name="tiny-paged-mixed", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
    layer_pattern=("attn", "local"), window=12,
    ternary=TernaryConfig(das=DasConfig(16, 8)),
    lpsa=LpsaConfig(sink=4, window=12, chunk=8),
    dtype="float32", remat=False, scan_layers=False,
)
RT = Runtime(serve_sparse=False)
MAX_LEN = 48
PAGE = 8


@pytest.fixture(scope="module")
def sparams_full():
    params = MD.init_params(jax.random.PRNGKey(0), CFG_FULL)
    return MD.export_serving(params, CFG_FULL)


@pytest.fixture(scope="module")
def sparams_mixed():
    params = MD.init_params(jax.random.PRNGKey(0), CFG_MIXED)
    return MD.export_serving(params, CFG_MIXED)


# -------------------------------------------------------------------------
# (a) the cache factory
# -------------------------------------------------------------------------

def test_cache_spec_factory_layouts():
    cfg = CFG_FULL
    full = KV.init_cache(cfg, KV.CacheSpec("full", batch=2, max_len=16))
    assert full["k"].shape == (2, 16, cfg.n_kv_heads, cfg.head_dim_)
    assert np.all(np.asarray(full["pos"]) == -1)

    ring = KV.init_cache(cfg, KV.CacheSpec("ring", batch=2, sink=4, window=8))
    assert ring["k"].shape == (2, 12, cfg.n_kv_heads, cfg.head_dim_)

    paged = KV.init_cache(cfg, KV.CacheSpec("paged", batch=2, page_size=4,
                                            num_pages=7))
    assert paged["k_pages"].shape == (7, 4, cfg.n_kv_heads, cfg.head_dim_)
    assert np.all(np.asarray(paged["pos_pages"]) == -1)
    assert KV.is_paged(paged) and not KV.is_paged(full)


def test_cache_spec_validation():
    with pytest.raises(ValueError, match="layout"):
        KV.CacheSpec("banana", batch=1)
    with pytest.raises(ValueError):
        KV.CacheSpec("paged", batch=1, page_size=0, num_pages=4)
    with pytest.raises(ValueError):
        KV.CacheSpec("paged", batch=1, page_size=4, num_pages=1)


def test_deprecated_init_shims_warn_and_match():
    with pytest.warns(DeprecationWarning):
        old = KV.init_attn_full(CFG_FULL, 2, 16, jnp.float32)
    new = KV.init_cache(CFG_FULL, KV.CacheSpec("full", batch=2, max_len=16,
                                               dtype=jnp.float32))
    for name in ("k", "v", "pos"):
        np.testing.assert_array_equal(np.asarray(old[name]),
                                      np.asarray(new[name]))


# -------------------------------------------------------------------------
# (b) paged write/read == dense full write/read
# -------------------------------------------------------------------------

def test_paged_write_read_matches_full(rng):
    cfg, B, L, ps = CFG_FULL, 3, 16, 4
    n = L // ps
    full = KV.init_cache(cfg, KV.CacheSpec("full", batch=B, max_len=L,
                                           dtype=jnp.float32))
    paged = KV.init_cache(cfg, KV.CacheSpec("paged", batch=B, page_size=ps,
                                            num_pages=B * n + 1,
                                            dtype=jnp.float32))
    # slot b owns pages [1 + b*n, 1 + (b+1)*n)
    pt = jnp.asarray(1 + np.arange(B * n, dtype=np.int32).reshape(B, n))
    for t in range(10):
        k = jnp.asarray(rng.standard_normal((B, 1, cfg.n_kv_heads,
                                             cfg.head_dim_)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, 1, cfg.n_kv_heads,
                                             cfg.head_dim_)), jnp.float32)
        ts = jnp.full((B,), t)
        full = KV.attn_write(full, k, v, ts, sink=0, window=0, ring=False)
        paged = KV.attn_write(paged, k, v, ts, sink=0, window=0, ring=False,
                              page_table=pt)
    fk, fv, fpos = KV.attn_read(full)
    pk, pv, ppos = KV.attn_read(paged, pt)
    np.testing.assert_array_equal(np.asarray(fk), np.asarray(pk))
    np.testing.assert_array_equal(np.asarray(fv), np.asarray(pv))
    np.testing.assert_array_equal(np.asarray(fpos), np.asarray(ppos))
    # inactive rows (t = -1) route to the null page, which stays masked
    paged = KV.attn_write(paged, k, v, jnp.full((B,), -1), sink=0, window=0,
                          ring=False, page_table=pt)
    assert np.all(np.asarray(paged["pos_pages"][0]) == -1)


# -------------------------------------------------------------------------
# (c) kvpool units
# -------------------------------------------------------------------------

def test_page_pool_refcounts():
    pool = PagePool(num_pages=4, page_size=8)
    a, b, c = pool.alloc(), pool.alloc(), pool.alloc()
    assert sorted((a, b, c)) == [1, 2, 3] and pool.alloc() is None
    pool.retain([b])
    assert pool.release([b]) == []          # still held once
    assert pool.release([b]) == [b]         # now free
    assert pool.release([a, c]) == [a, c]
    assert pool.pages_in_use == 0
    with pytest.raises(RuntimeError):
        pool.release([a])                   # double free
    with pytest.raises(RuntimeError):
        pool.retain([a])                    # retain of free page


def test_radix_lookup_exact_and_donor():
    idx = RadixIndex()
    e1 = PrefixEntry(length=4, pages=(1, 2))
    e2 = PrefixEntry(length=6, pages=(1, 2, 3))
    assert idx.insert((5, 6, 7, 8), e1)
    assert not idx.insert((5, 6, 7, 8), e1)          # duplicate
    assert idx.insert((5, 6, 7, 8, 9, 10), e2)
    best, donor, common = idx.lookup((5, 6, 7, 8, 9, 10, 11))
    assert best is e2 and common == 6
    # diverges after 5 tokens: deepest registered ancestor is e1, but the
    # common prefix with e2's sequence is longer and e2 can donate pages
    best, donor, common = idx.lookup((5, 6, 7, 8, 9, 99))
    assert best is e1 and donor is e2 and common == 5
    best, donor, common = idx.lookup((42,))
    assert best is None and common == 0
    assert idx.remove((5, 6, 7, 8)) is e1
    best, _, _ = idx.lookup((5, 6, 7, 8, 9, 99))
    assert best is None                               # e1 gone
    assert len(idx) == 1


# -------------------------------------------------------------------------
# (d)-(g) engine integration
# -------------------------------------------------------------------------

def _trace(prompts, gen=10, stagger=3, temp=0.8):
    return [Request(uid=i, prompt=p, max_new_tokens=gen, temperature=temp,
                    arrival=stagger * i) for i, p in enumerate(prompts)]


def _prompts(seed=0, lens=(11, 17, 9, 11)):
    rng = np.random.default_rng(seed)
    ps = [np.asarray(rng.integers(0, 256, (int(l),)), np.int32) for l in lens]
    ps[3] = ps[0].copy()           # duplicate prompt -> exact prefix hit
    return ps


@pytest.mark.parametrize("which", ["full", "mixed"])
def test_paged_engine_token_parity(which, sparams_full, sparams_mixed):
    cfg, sp = ((CFG_FULL, sparams_full) if which == "full"
               else (CFG_MIXED, sparams_mixed))
    dense = ServeEngine(cfg, sp, RT,
                        config=ServeConfig(max_slots=2, max_len=MAX_LEN))
    paged = ServeEngine(cfg, sp, RT,
                        config=ServeConfig(max_slots=2, max_len=MAX_LEN,
                                           layout="paged", page_size=PAGE))
    for r in _trace(_prompts()):
        dense.submit(r)
    for r in _trace(_prompts()):
        paged.submit(r)
    rd, rp = dense.run(), paged.run()
    assert set(rd) == set(rp)
    for uid in rd:
        np.testing.assert_array_equal(rd[uid].tokens, rp[uid].tokens)
    assert paged.stats.prefix_hits >= 1          # the duplicate prompt


def test_prefix_sharing_prefills_fewer_tokens(sparams_full):
    mk = lambda share: ServeEngine(
        CFG_FULL, sparams_full, RT,
        config=ServeConfig(max_slots=2, max_len=MAX_LEN, layout="paged",
                           page_size=PAGE, prefix_sharing=share))
    rng = np.random.default_rng(1)
    stem = rng.integers(0, 256, (24,))
    prompts = [np.asarray(np.concatenate([stem, rng.integers(0, 256, (4,))]),
                          np.int32) for _ in range(4)]
    on, off = mk(True), mk(False)
    for r in _trace(prompts, stagger=6):
        on.submit(r)
    for r in _trace(prompts, stagger=6):
        off.submit(r)
    ron, roff = on.run(), off.run()
    assert on.stats.prefill_tokens < off.stats.prefill_tokens
    assert on.stats.prompt_tokens_reused > 0
    # sharing is an optimization, not a sampler change: greedy outputs at
    # temperature 0 would match; here just check both produced full results
    assert set(ron) == set(roff)


def test_cow_and_refcounts_interleaved(sparams_full):
    eng = ServeEngine(CFG_FULL, sparams_full, RT,
                      config=ServeConfig(max_slots=2, max_len=MAX_LEN,
                                         layout="paged", page_size=PAGE))
    rng = np.random.default_rng(2)
    stem = rng.integers(0, 256, (12,))   # not page-aligned: boundary CoW
    mk = lambda uid, arrive: Request(
        uid=uid,
        prompt=np.asarray(np.concatenate([stem,
                                          rng.integers(0, 256, (3,))]),
                          np.int32),
        max_new_tokens=8, temperature=0.5, arrival=arrive)
    # wave 1 registers the prefix; wave 2 arrives after wave 1 retires and
    # must CoW the trie-held partial boundary page
    for i in range(2):
        eng.submit(mk(i, 0))
    for i in range(2, 4):
        eng.submit(mk(i, 40))
    res = eng.run()
    assert len(res) == 4
    assert eng.stats.cow_copies >= 1
    pool = eng._pool
    # drained: only trie entries hold pages now, each exactly once per holder
    held = {pg for _, e in eng._radix.items() for pg in e.pages}
    assert {int(p) for p in np.nonzero(pool.refs)[0]} == held
    trie_holds = {}
    for _, e in eng._radix.items():
        for pg in e.pages:
            trie_holds[pg] = trie_holds.get(pg, 0) + 1
    for pg, c in trie_holds.items():
        assert pool.refs[pg] == c


def test_pool_memory_tracks_live_tokens(sparams_full):
    eng = ServeEngine(CFG_FULL, sparams_full, RT,
                      config=ServeConfig(max_slots=4, max_len=MAX_LEN,
                                         layout="paged", page_size=PAGE,
                                         prefix_sharing=False))
    rng = np.random.default_rng(3)
    prompts = [np.asarray(rng.integers(0, 256, (9,)), np.int32)
               for _ in range(4)]
    for r in _trace(prompts, gen=6, stagger=0):
        eng.submit(r)
    eng.run()
    pool = eng.pool_stats()
    # live tokens never exceeded 4 * (9 + 6) = 60 -> at most
    # 4 * ceil(15/8) = 8 pages, far below the 4 * 48/8 = 24 dense pages
    live_worst = 4 * (-(-(9 + 6) // PAGE))
    assert 0 < pool["pages_peak"] <= live_worst
    assert pool["pages_peak"] * pool["page_bytes"] < pool["dense_equiv_bytes"]
    assert pool["pages_in_use"] == 0     # drained, nothing pinned
    # dense equivalent would pin max_slots * max_len rows regardless
    assert pool["dense_equiv_bytes"] == 4 * (MAX_LEN // PAGE) \
        * pool["page_bytes"]


def test_paged_pool_exhaustion_defers_not_crashes(sparams_full):
    # pool sized for ~1.5 sequences: admissions must defer, not die, and
    # every request still completes
    eng = ServeEngine(CFG_FULL, sparams_full, RT,
                      config=ServeConfig(max_slots=2, max_len=MAX_LEN,
                                         layout="paged", page_size=PAGE,
                                         num_pages=4))
    rng = np.random.default_rng(4)
    prompts = [np.asarray(rng.integers(0, 256, (10,)), np.int32)
               for _ in range(3)]
    for r in _trace(prompts, gen=8, stagger=0):
        eng.submit(r)
    res = eng.run()
    assert len(res) == 3
    with pytest.raises(ValueError, match="pages"):
        eng.submit(Request(uid=99,
                           prompt=np.asarray(rng.integers(0, 256, (30,)),
                                             np.int32),
                           max_new_tokens=10, temperature=0.0, arrival=0))


# -------------------------------------------------------------------------
# (h) deprecation shims warn once per process; (i) pool accounting across a
#     retire->admit cycle with recurrent per-slot state in the mix
# -------------------------------------------------------------------------

def test_deprecated_shims_warn_exactly_once_per_process():
    """Each legacy constructor warns on first use only (the warned-set is
    process-global) and returns the exact init_cache(CacheSpec(...)) tree."""
    import warnings as W
    from repro.configs import get_config, reduced
    zcfg = reduced(get_config("zamba2-2.7b"))     # has ssm for the mamba shim
    cases = [
        ("init_attn_ring", lambda: KV.init_attn_ring(CFG_FULL, 2, 4, 8),
         lambda: KV.init_cache(CFG_FULL, KV.CacheSpec("ring", 2, sink=4,
                                                      window=8))),
        ("init_mamba_state", lambda: KV.init_mamba_state(zcfg, 2),
         lambda: KV.init_cache(zcfg, KV.CacheSpec("mamba", 2))),
        ("init_rwkv_state", lambda: KV.init_rwkv_state(CFG_FULL, 2),
         lambda: KV.init_cache(CFG_FULL, KV.CacheSpec("rwkv", 2))),
        ("init_gla_state", lambda: KV.init_gla_state(CFG_FULL, 2),
         lambda: KV.init_cache(CFG_FULL, KV.CacheSpec("gla", 2))),
    ]
    for name, shim, factory in cases:
        KV._DEPRECATION_WARNED.discard(name)      # deterministic first use
        with pytest.warns(DeprecationWarning, match=name):
            old = shim()
        with W.catch_warnings():
            W.simplefilter("error", DeprecationWarning)
            again = shim()                        # second call: silent
        new = factory()
        assert set(old) == set(new) == set(again)
        for k in new:
            np.testing.assert_array_equal(np.asarray(old[k]),
                                          np.asarray(new[k]), err_msg=name)


CFG_HYBRID = ModelConfig(
    name="tiny-paged-hybrid", family="hybrid", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
    layer_pattern=("mamba", "attn"),
    ternary=TernaryConfig(das=DasConfig(16, 8)),
    ssm=SsmConfig(16, 16, 2, 4, chunk=8),
    dtype="float32", remat=False, scan_layers=False,
)


def test_pool_stats_survive_retire_admit_with_recurrent_layers():
    """Hybrid paged engine: the attn layer pages through the shared arena
    while the mamba layer keeps per-slot recurrent rows.  Page accounting
    must balance across retire->admit cycles (no refcount leak), retired
    slots' recurrent rows are scrubbed to zero, and a replay of the same
    trace peaks at the same page count."""
    params = MD.init_params(jax.random.PRNGKey(2), CFG_HYBRID)
    sp = MD.export_serving(params, CFG_HYBRID)
    eng = ServeEngine(CFG_HYBRID, sp, RT,
                      config=ServeConfig(max_slots=2, max_len=MAX_LEN,
                                         layout="paged", page_size=PAGE,
                                         prefix_sharing=False))
    rows = eng.layout_summary()
    assert [r["layout"] for r in rows] == ["mamba", "paged"]
    prompts = _prompts(seed=7, lens=(11, 17, 9, 13))
    for r in _trace(prompts, gen=6, stagger=2):
        eng.submit(r)
    res1 = eng.run()
    assert len(res1) == 4
    pool1 = eng.pool_stats()
    assert pool1["pages_in_use"] == 0             # all retired -> all freed
    assert pool1["pages_peak"] > 0
    # retired recurrent rows are scrubbed (mamba is the first tail layer).
    # conv/ssd replay buffers may pick up don't-care writes from later
    # ticks of the shared batched step (inactive rows still flow through
    # it, exactly like inactive attention rows) — but the ssm carry only
    # changes on a chunk fold, which inactive rows never reach, so the
    # scrubbed zero must survive to drain.
    mstate = eng.caches["tail"][0]
    assert float(jnp.abs(mstate["ssm"]).max()) == 0.0
    # second wave through the SAME engine: accounting must not drift
    eng.reset_clock()
    for r in _trace(prompts, gen=6, stagger=2):
        eng.submit(r)
    res2 = eng.run()
    pool2 = eng.pool_stats()
    assert pool2["pages_in_use"] == 0
    assert pool2["pages_peak"] == pool1["pages_peak"]
    for uid in res1:
        np.testing.assert_array_equal(res1[uid].tokens, res2[uid].tokens)
