"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import das, twd
from repro.kernels import ops, ref


@pytest.mark.parametrize("k,n", [(320, 128), (640, 256), (1600, 512)])
def test_twd_decode_kernel(rng, k, n):
    trits = rng.integers(-1, 2, size=(k, n)).astype(np.int8)
    packed = jnp.asarray(twd.pack_ternary(trits))
    out = np.asarray(ops.twd_decode(packed, k, mode="interpret"))
    assert np.array_equal(out, trits)


@pytest.mark.parametrize("m,k,n,dtype", [
    (8, 320, 128, "float32"), (16, 640, 256, "bfloat16"),
    (128, 960, 512, "float32"), (1, 320, 256, "float32"),
])
def test_ternary_gemm_kernel(rng, m, k, n, dtype):
    trits = rng.integers(-1, 2, size=(k, n)).astype(np.int8)
    packed = jnp.asarray(twd.pack_ternary(trits))
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.dtype(dtype))
    y = np.asarray(ops.ternary_gemm(x, packed, 0.5, mode="interpret"))
    yr = np.asarray(ref.ternary_gemm_packed_ref(x, packed, 0.5, k))
    tol = 2e-2 if dtype == "bfloat16" else 1e-4
    np.testing.assert_allclose(y, yr, rtol=tol, atol=tol)


def test_ternary_gemm_int8_exact(rng):
    k, n, m = 640, 256, 8
    trits = rng.integers(-1, 2, size=(k, n)).astype(np.int8)
    packed = jnp.asarray(twd.pack_ternary(trits))
    xi = jnp.asarray(rng.integers(-127, 128, size=(m, k)), jnp.int8)
    xsc = jnp.asarray(rng.random((m, 1)) + 0.5, jnp.float32)
    y = np.asarray(ops.ternary_gemm(xi, packed, 0.37, xsc, mode="interpret"))
    yr = np.asarray(ref.ternary_gemm_packed_ref(xi, packed, 0.37, k, xsc))
    np.testing.assert_allclose(y, yr, rtol=1e-6, atol=1e-6)  # exact int path


@pytest.mark.parametrize("m,k,keep", [(64, 512, 16), (128, 1024, 8),
                                      (32, 2048, 24)])
def test_topk_mask_kernel(rng, m, k, keep):
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    mk = np.asarray(ops.topk_mask(x, keep=keep, mode="interpret"))
    mr = np.asarray(ref.das_topk_mask_ref(x, block_size=32, keep=keep))
    mc = np.asarray(das.das_mask(x, block_size=32, keep=keep))
    assert np.array_equal(mk.astype(bool), mr)
    assert np.array_equal(mr, mc)  # three formulations agree


@pytest.mark.parametrize("k,n", [(512, 256), (1024, 512), (2048, 256)])
def test_das_gemv_kernel(rng, k, n):
    xv = jnp.asarray(rng.standard_normal((k,)), jnp.float32)
    ca = das.das_compact(xv[None], block_size=32, keep=16)
    w = jnp.asarray(rng.integers(-1, 2, size=(k, n)), jnp.int8)
    g = np.asarray(ops.das_gemv(ca.values[0], ca.indices[0], w, 0.5,
                                keep=16, mode="interpret"))
    gr = np.asarray(ref.das_gemv_ref(ca.values[0], ca.indices[0], w, 0.5))
    np.testing.assert_allclose(g, gr, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("hq,hkv,lq,lk,cap", [
    (4, 2, 256, 256, None), (4, 4, 128, 256, 30.0), (8, 1, 256, 128, None),
])
def test_sparse_attention_kernel(rng, hq, hkv, lq, lk, cap):
    B, D = 2, 64
    q = jnp.asarray(rng.standard_normal((B, hq, lq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, hkv, lk, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, hkv, lk, D)), jnp.float32)
    qp = jnp.broadcast_to(jnp.arange(lq) + (lk - lq), (B, lq)).astype(jnp.int32)
    kp = jnp.broadcast_to(jnp.arange(lk), (B, lk)).astype(jnp.int32)
    a = np.asarray(ops.sparse_attention(q, k, v, qp, kp, sink=16, window=64,
                                        softcap=cap, mode="interpret"))
    b = np.asarray(ops.sparse_attention(q, k, v, qp, kp, sink=16, window=64,
                                        softcap=cap, mode="ref"))
    np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


def test_sparse_attention_ring_positions(rng):
    """Scrambled slot->position maps with empties (decode ring layout)."""
    B, Hq, Hkv, D, Lk = 2, 4, 2, 64, 128
    kp = np.concatenate([np.arange(8), 64 + (np.arange(56) + 7) % 56,
                         -np.ones(64)]).astype(np.int32)
    kp = jnp.asarray(np.broadcast_to(kp, (B, Lk)).copy())
    qp = jnp.full((B, 1), 120, jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, Hq, 1, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Lk, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Lk, D)), jnp.float32)
    a = np.asarray(ops.sparse_attention(q, k, v, qp, kp, sink=8, window=56,
                                        mode="interpret"))
    b = np.asarray(ops.sparse_attention(q, k, v, qp, kp, sink=8, window=56,
                                        mode="ref"))
    np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)
