"""split_stages + GPipe schedule shape properties (single-device checks;
numeric equivalence lives in test_multidevice.py)."""
from repro.distributed.pipeline import split_stages


def test_split_stages_partitions():
    seq = tuple(range(10))
    st = split_stages(seq, 2)
    assert st == ((0, 1, 2, 3, 4), (5, 6, 7, 8, 9))
    st3 = split_stages(seq, 3)
    assert sum(len(s) for s in st3) == 10
    assert all(len(s) <= 4 for s in st3)
