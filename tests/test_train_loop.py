"""End-to-end training loop: loss decreases; checkpoint-resume bitwise."""
import numpy as np

from repro.launch import train as T


def test_tiny_training_reduces_loss():
    losses = T.main(["--arch", "bitnet-1.3b", "--reduced", "--steps", "30",
                     "--batch", "4", "--seq", "64", "--log-every", "100"])
    assert losses[-1] < losses[0] - 0.05


def test_fault_injection_run(tmp_path):
    losses = T.main(["--arch", "stablelm-1.6b", "--reduced", "--steps", "16",
                     "--batch", "2", "--seq", "32", "--ckpt-dir",
                     str(tmp_path), "--ckpt-every", "4",
                     "--inject-failure", "6", "--log-every", "100"])
    clean = T.main(["--arch", "stablelm-1.6b", "--reduced", "--steps", "16",
                    "--batch", "2", "--seq", "32", "--log-every", "100"])
    np.testing.assert_allclose(losses[-1], clean[-1], rtol=1e-5)
