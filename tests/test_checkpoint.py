"""Checkpoint: roundtrip, commit marker, async, latest, resharding restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint, wait_pending)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "b": {"c": jnp.arange(7), "d": (jnp.ones((3,)), jnp.zeros((2, 2)))}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    got, step = restore_checkpoint(str(tmp_path))
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_multiple(tmp_path):
    for s in (1, 5, 12):
        save_checkpoint(str(tmp_path), s, _tree(s))
    assert latest_step(str(tmp_path)) == 12
    got, step = restore_checkpoint(str(tmp_path), 5)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(_tree(5)["a"]))


def test_async_save(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t, async_save=True)
    wait_pending()
    got, _ = restore_checkpoint(str(tmp_path))
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))


def test_uncommitted_ignored(tmp_path):
    save_checkpoint(str(tmp_path), 2, _tree())
    d = os.path.join(str(tmp_path), "step_00000007")
    os.makedirs(d)  # no DONE marker
    assert latest_step(str(tmp_path)) == 2


def test_resharding_restore(tmp_path):
    from jax.sharding import PartitionSpec as P
    t = {"w": jnp.arange(32.0).reshape(8, 4)}
    save_checkpoint(str(tmp_path), 1, t)
    mesh = jax.make_mesh((1,), ("data",))
    got, _ = restore_checkpoint(str(tmp_path), mesh=mesh,
                                specs={"w": P("data", None)})
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))
    assert got["w"].sharding.is_equivalent_to(
        jax.NamedSharding(mesh, P("data", None)), 2)
