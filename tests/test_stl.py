"""STL-core LUT semantics (Sec. III-B): bit-exact equivalence + Table I."""
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import stl


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 32), st.integers(1, 24),
       st.integers(1, 8))
def test_stl_equals_matmul(seed, g, n, m):
    k = 2 * g
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.integers(-1, 2, size=(k, n)), jnp.int8)
    out = np.asarray(stl.stl_matmul_ref(x, w))
    ref = np.asarray(x) @ np.asarray(w, np.float32)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-4)


def test_encoding_covers_all_nine_pairs():
    w = jnp.asarray([[a, b] for a in (-1, 0, 1) for b in (-1, 0, 1)],
                    jnp.int8).T  # (2, 9): one group, 9 channels
    enc = stl.stl_encode(w)
    # zero gate fires exactly for the (0, 0) pair
    assert np.asarray(enc.gidx).sum() == 1
    x = jnp.asarray([[1.7, -0.3]], jnp.float32)
    out = np.asarray(stl.stl_decode_dot(x, enc))[0]
    ref = (np.asarray(x) @ np.asarray(w, np.float32))[0]
    np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-6)


def test_table1_complexity_ordering():
    kw = dict(n_t=64, g_total=16, g=2)
    add = stl.core_complexity("add_only", **kw)
    gen = stl.core_complexity("general_lut", **kw)
    ter = stl.core_complexity("ternary_lut", **kw)
    ours = stl.core_complexity("stl", **kw, s_a=1.0)
    # STL: smaller table than base-3 ternary LUT, smaller adder than add-only
    assert ours["lookup"] < ter["lookup"]
    assert ours["adder"] < add["adder"]
    assert ours["adder"] <= gen["adder"] * 2  # comparable adder to bitwise
    # DAS scales every term by S_a
    half = stl.core_complexity("stl", **kw, s_a=0.5)
    for k2 in ("precompute", "lookup", "adder"):
        assert np.isclose(half[k2], 0.5 * ours[k2])
