"""Serving the model zoo: batch invariance for every slot-state family.

A request's tokens must be bitwise independent of its batch-mates for
EVERY layout in the engine's slot-state union — not just the attention
KV caches test_serve_engine.py covers, but mamba chunk-replay state
(hybrid), rwkv wkv/shift state, the gla state matrix, and MoE routing.
MoE is the sharpest case: the training-time expert capacity
``t * top_k / E * cf`` would let a momentarily hot expert drop whichever
request happened to share the decode tick, so the configs here force a
production-tight ``capacity_factor=1.0`` and rely on the engine's
no-drop decode capacity (models/moe.decode_capacity).

Same joint-vs-solo assertion style as test_serve_engine.py: replay a
staggered-admission trace, then each request alone, and require exact
token equality.
"""
import dataclasses
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.serve import Request, ServeConfig, ServeEngine

pytestmark = pytest.mark.slow

# one config per slot-state family (attn-only is test_serve_engine.py's job)
FAMILIES = ["zamba2-2.7b",        # mamba/attn hybrid, shared attention
            "rwkv6-3b",           # pure rwkv recurrent
            "gla-1.3b",           # pure gla recurrent
            "qwen3-moe-30b-a3b"]  # MoE FFN over LPSA attention


@functools.lru_cache(maxsize=None)
def _setup(arch):
    cfg = reduced(get_config(arch))
    if cfg.moe is not None:
        # reduced() relaxes capacity to "no drops anywhere"; restore a
        # production-tight factor so this test would FAIL if decode ever
        # fell back to the capacity-factor formula
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    p = MD.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, MD.export_serving(p, cfg)


def _trace(cfg):
    # prompt lengths straddle the ssm/lpsa chunk (16 under reduced()): the
    # hybrid config exercises prefill state handoff at a non-boundary AND
    # decode-side chunk folds; generation crosses a fold for every slot
    rng = np.random.default_rng(0)
    spec = [(18, 8, 0, 0.0), (23, 6, 2, 0.9), (10, 7, 4, 0.7)]
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, p).astype(np.int32),
                    max_new_tokens=g, arrival=a, temperature=tp)
            for i, (p, g, a, tp) in enumerate(spec)]


@pytest.mark.parametrize("arch", FAMILIES)
def test_zoo_batch_invariance(arch):
    cfg, sparams = _setup(arch)
    rt = Runtime()
    sc = ServeConfig(max_slots=2, max_len=64)
    trace = _trace(cfg)
    eng = ServeEngine(cfg, sparams, rt, sc)
    for r in trace:
        eng.submit(r)
    joint = eng.run()
    assert set(joint) == {r.uid for r in trace}
    for r in trace:
        solo_eng = ServeEngine(cfg, sparams, rt, sc)
        solo_eng.submit(r)
        solo = solo_eng.run()[r.uid]
        np.testing.assert_array_equal(solo.tokens, joint[r.uid].tokens)
        assert len(joint[r.uid].tokens) == r.max_new_tokens


def test_moe_expert_capacity_admission_control():
    """moe_expert_capacity throttles ADMISSION, never tokens: with the
    bound at 1 the engine serializes requests (each admitted into an empty
    batch), defers the rest, and still produces the exact tokens of the
    unbounded run."""
    cfg, sparams = _setup("qwen3-moe-30b-a3b")
    rt = Runtime()
    trace = _trace(cfg)

    free = ServeEngine(cfg, sparams, rt, ServeConfig(max_slots=2, max_len=64))
    for r in trace:
        free.submit(r)
    unbounded = free.run()
    assert free.stats.moe_capacity_deferrals == 0

    capped = ServeEngine(cfg, sparams, rt,
                         ServeConfig(max_slots=2, max_len=64,
                                     moe_expert_capacity=1))
    for r in trace:
        capped.submit(r)
    serial = capped.run()
    assert capped.stats.moe_capacity_deferrals > 0
    for uid, res in serial.items():
        assert res.admitted_with_active == 0      # never co-resident
        np.testing.assert_array_equal(res.tokens, unbounded[uid].tokens)


def test_layout_summary_matches_layer_kinds():
    cfg, sparams = _setup("zamba2-2.7b")
    eng = ServeEngine(cfg, sparams, Runtime(),
                      ServeConfig(max_slots=2, max_len=64))
    rows = eng.layout_summary()
    assert [r["kind"] for r in rows] == list(cfg.layer_kinds())
    assert all(r["layout"] == "mamba" for r in rows if r["kind"] == "mamba")
    # shared-attn layers ride the LPSA ring under serve_sparse
    assert all(r["layout"] == "ring" for r in rows if r["kind"] == "attn")
