"""Sharding rules: spec assignment, ZeRO-1 divisibility, cache specs.

Exercises the rules through the ShardingPlan API (distributed/plan.py);
the legacy ``sharding.param_specs``/``zero1_specs`` shims get their own
warn-once coverage in test_sharding_plan.py.
"""
import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.distributed.plan import ShardingPlan, Topology
from repro.models import model as MD


def _plan(arch, topo=None):
    cfg = reduced(get_config(arch))
    p = jax.eval_shape(lambda: MD.init_params(jax.random.PRNGKey(0), cfg))
    return p, ShardingPlan.for_tree(p, topo, validate=False)


def test_attention_tp_pattern():
    p, plan = _plan("bitnet-1.3b")
    blk = plan.params["layers"]["tail"][0]
    assert blk["attn"]["wq"]["w"] == P(None, "model")
    assert blk["attn"]["wo"]["w"] == P("model", None)
    assert blk["ffn"]["w_in"]["w"] == P(None, "model")
    assert blk["ffn"]["w_out"]["w"] == P("model", None)
    assert plan.params["embed"] == P("model", None)
    assert blk["norm1"]["scale"] == P()


def test_moe_expert_parallel():
    p, plan = _plan("qwen3-moe-30b-a3b")
    blk = plan.params["layers"]["tail"][0]
    assert blk["moe"]["experts_gate"]["w"] == P("model", None, None)
    assert blk["moe"]["router"] in (P(), P(None, None))


def test_stacked_gets_group_axis():
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config("bitnet-1.3b")),
                              n_layers=4, scan_layers=True)
    p = jax.eval_shape(lambda: MD.init_params(jax.random.PRNGKey(0), cfg))
    plan = ShardingPlan.for_tree(p, validate=False)
    assert plan.params["layers"]["stacked"][0]["attn"]["wq"]["w"] == \
        P(None, None, "model")


def test_zero1_divisibility():
    p, plan = _plan("bitnet-1.3b", Topology(dp=16))
    z = plan.zero1(p)
    leaves = jax.tree_util.tree_flatten_with_path(
        z, is_leaf=lambda x: isinstance(x, P))[0]
    shapes = jax.tree_util.tree_flatten_with_path(p)[0]
    for (kp, spec), (_, shp) in zip(leaves, shapes):
        for i, ax in enumerate(spec):
            if ax == "data":
                assert shp.shape[i] % 16 == 0, (kp, spec, shp.shape)


def test_serving_params_shardable():
    cfg = reduced(get_config("qwen3-moe-30b-a3b"))
    sp = jax.eval_shape(lambda: MD.export_serving(
        MD.init_params(jax.random.PRNGKey(0), cfg), cfg))
    plan = ShardingPlan.for_tree(sp, validate=False)
    # packed expert weights shard on the expert axis
    blk = plan.params["layers"]["tail"][0]["moe"]
    assert blk["experts_gate"]["packed"] == P("model", None, None)
