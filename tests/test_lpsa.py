"""LPSA dataflow (Sec. IV-B): streaming == quadratic oracle, ring eviction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lpsa


def _proj(dm, hq, hkv, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    wq = jax.random.normal(ks[0], (dm, hq * d)) * 0.2
    wk = jax.random.normal(ks[1], (dm, hkv * d)) * 0.2
    wv = jax.random.normal(ks[2], (dm, hkv * d)) * 0.2

    def f(p):
        b, c, _ = p.shape
        return ((p @ wq).reshape(b, c, hq, d), (p @ wk).reshape(b, c, hkv, d),
                (p @ wv).reshape(b, c, hkv, d))
    return f


@pytest.mark.parametrize("sink,window,chunk", [
    (4, 16, 8), (0, 8, 4), (8, 8, 16), (2, 30, 8), (4, 12, 32),
])
def test_streaming_prefill_matches_oracle(sink, window, chunk):
    B, L, Hq, Hkv, D, DM = 2, 64, 4, 2, 8, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, L, DM))
    proj = _proj(DM, Hq, Hkv, D)
    spec = lpsa.LpsaSpec(sink=sink, window=window, chunk=chunk)
    o = lpsa.lpsa_prefill(x, proj, spec=spec, num_q_heads=Hq,
                          num_kv_heads=Hkv, head_dim=D)
    q, k, v = proj(x)
    ref = lpsa.masked_attention_ref(q, k, v, sink=sink, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


def test_mask_row_budget():
    m = np.asarray(lpsa.lpsa_mask(256, 16, 48))
    counts = m.sum(-1)
    # every row attends exactly TL_SA = sink + window keys once warmed up
    assert counts.max() <= 16 + 48
    assert counts[-1] == 16 + 48
    assert np.all(np.triu(m, 1) == 0)
    assert np.all(m[:, 0][16:])  # sink column always visible


def test_decode_ring_with_eviction():
    """Ring cache beyond capacity must equal the quadratic oracle."""
    B, Hq, Hkv, D = 2, 4, 2, 8
    sink, window = 4, 12
    L = 48  # > sink + window: eviction exercised
    key = jax.random.PRNGKey(2)
    k_all = jax.random.normal(key, (B, L, Hkv, D))
    v_all = jax.random.normal(jax.random.PRNGKey(3), (B, L, Hkv, D))
    q_all = jax.random.normal(jax.random.PRNGKey(4), (B, L, Hq, D))

    kc = jnp.zeros((B, sink + window, Hkv, D))
    vc = jnp.zeros_like(kc)
    pos = jnp.full((sink + window,), -1, jnp.int32)
    outs = []
    for t in range(L):
        slot = int(lpsa.decode_slot(jnp.array(t), sink, window))
        kc = kc.at[:, slot].set(k_all[:, t])
        vc = vc.at[:, slot].set(v_all[:, t])
        pos = pos.at[slot].set(t)
        o = lpsa.lpsa_decode_attend(q_all[:, t:t+1], kc, vc,
                                    jnp.broadcast_to(pos, (B, sink + window)),
                                    jnp.full((B,), t), sink=sink,
                                    window=window)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    ref = lpsa.masked_attention_ref(q_all, k_all, v_all, sink=sink,
                                    window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


def test_allowed_semantics():
    qp = jnp.array([100])
    assert bool(lpsa.lpsa_allowed(qp, jnp.array([3]), 4, 16))       # sink
    assert bool(lpsa.lpsa_allowed(qp, jnp.array([85]), 4, 16))      # window edge
    assert not bool(lpsa.lpsa_allowed(qp, jnp.array([84]), 4, 16))  # evicted
    assert not bool(lpsa.lpsa_allowed(qp, jnp.array([101]), 4, 16))  # future
    # ring-consistency: every visible non-sink key maps to a distinct slot
    qs = 100
    vis = [p for p in range(qs + 1)
           if bool(lpsa.lpsa_allowed(jnp.array([qs]), jnp.array([p]), 4, 16))
           and p >= 4]
    slots = [int(lpsa.decode_slot(jnp.array(p), 4, 16)) for p in vis]
    assert len(set(slots)) == len(slots)
