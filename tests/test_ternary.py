"""Unit + property tests for Q_1.58 / Q_int8 quantizers (paper Sec. III)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import ternary as tq


def test_values_are_ternary(rng):
    w = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    tw = tq.ternary_quantize(w)
    assert set(np.unique(np.asarray(tw.values))) <= {-1, 0, 1}
    assert tw.values.dtype == jnp.int8


def test_absmean_scale(rng):
    w = jnp.asarray(rng.standard_normal((128, 32)), jnp.float32)
    np.testing.assert_allclose(float(tq.absmean_scale(w)),
                               float(jnp.mean(jnp.abs(w))) + 1e-6, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 64), st.integers(1, 32))
def test_dequant_error_bounded(seed, k, n):
    """round-to-nearest: |W/γ - q| <= 0.5 wherever |W/γ| <= 1.5."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    tw = tq.ternary_quantize(w)
    ratio = np.asarray(w / tw.scale)
    q = np.asarray(tw.values, np.float32)
    inner = np.abs(ratio) <= 1.5
    assert np.all(np.abs(ratio - q)[inner] <= 0.5 + 1e-5)


def test_ste_gradient_is_identity(rng):
    w = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    g = jax.grad(lambda w_: jnp.sum(tq.ternary_fake_quant(w_) * 3.0))(w)
    np.testing.assert_allclose(np.asarray(g), 3.0)
    x = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
    gx = jax.grad(lambda x_: jnp.sum(tq.int8_fake_quant(x_) * 2.0))(x)
    np.testing.assert_allclose(np.asarray(gx), 2.0)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_int8_roundtrip_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((8, 64)) * 5.0, jnp.float32)
    qa = tq.int8_quantize(x)
    back = tq.int8_dequantize(qa)
    # error bounded by half a quantization step per element
    step = np.asarray(qa.scale)
    assert np.all(np.abs(np.asarray(back - x)) <= 0.51 * step + 1e-6)


def test_ternary_matmul_ref(rng):
    w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    tw = tq.ternary_quantize(w)
    x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    y = tq.ternary_matmul_ref(x, tw.values, tw.scale)
    ref = np.asarray(x) @ (np.asarray(tw.values, np.float32) * float(tw.scale))
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-5, atol=1e-5)
