"""TWD export path: serving (packed/int8) outputs track the QAT fake-quant
forward, and packed weights really are 1.6 bits/weight."""
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.models.ternary_linear import export_tlin, tlin_apply, tlin_init

RT = Runtime()


def test_tlin_serving_matches_master():
    cfg = reduced(get_config("bitnet-1.3b"))
    tc = cfg.ternary
    p = tlin_init(jax.random.PRNGKey(0), 64, 32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    y_master = tlin_apply(p, x, tc)          # fake-quant path
    for fmt in ("packed", "int8"):
        tc2 = dataclasses.replace(tc, serve_format=fmt)
        sp = export_tlin(p, tc2)
        y_serve = tlin_apply(sp, x, tc2)
        # master path also int8-quantizes activations; serve path doesn't —
        # bounded divergence, same ternary weights
        np.testing.assert_allclose(np.asarray(y_serve), np.asarray(y_master),
                                   rtol=0.15, atol=0.15)


def test_packed_density():
    p = tlin_init(jax.random.PRNGKey(0), 4096, 1024)
    from repro.configs.base import TernaryConfig
    sp = export_tlin(p, TernaryConfig())
    bits = sp["packed"].size * 8 / (4096 * 1024)
    assert bits < 1.65


def test_export_whole_model_and_serve():
    cfg = reduced(get_config("qwen3-moe-30b-a3b"))
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    sparams = MD.export_serving(params, cfg)
    # every 2-D ternary master was converted
    names = [str(k) for k, _ in
             jax.tree_util.tree_flatten_with_path(sparams)[0]]
    assert any("packed" in n for n in names)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    lg, caches = MD.prefill(sparams, cfg, toks[:, :16], RT, max_len=32)
    assert bool(jnp.isfinite(lg[..., :cfg.vocab]).all())
    lg2, _ = MD.decode_step(sparams, cfg, caches, toks[:, 16], jnp.array(16), RT)
    assert bool(jnp.isfinite(lg2[..., :cfg.vocab]).all())


def test_serving_bytes_ratio():
    """Packed serving model ~8-10x smaller than f32 master (1.58b + fp norms)."""
    cfg = reduced(get_config("bitnet-1.3b"))
    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    sparams = MD.export_serving(params, cfg)
    master = sum(x.nbytes for x in jax.tree.leaves(params))
    serve = sum(x.nbytes for x in jax.tree.leaves(sparams))
    assert serve < master / 2  # embeddings dominate the tiny smoke model
