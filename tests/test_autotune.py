"""DSE-driven kernel autotuner (kernels/autotune) + tuned dispatch.

(a) cache: TileConfig round-trips through the on-disk JSON; a populated
    cache answers `tune` with ZERO timed candidate runs;
(b) ranking: `perfmodel.kernel_cost` orders the XLA-CPU implementations the
    way they actually measure (dense-mask decode-GEMMs beat the gather
    path; f32dec beats plain decode), and `tune`'s timed winner is one of
    the perfmodel's top-ranked candidates;
(c) parity: the tuned/compiled/interpret dispatches agree with the jnp
    reference over a hypothesis sweep of shapes and seeds;
(d) fallback accounting: shape-inadmissible layers under a kernel mode warn
    exactly once per shape and count every occurrence;
(e) engine: `kernel_mode="tuned"` produces token streams bitwise identical
    to "ref", and a second engine over the same shapes warms up from the
    cache without re-timing anything (`stats.autotune_timed_runs == 0`).
"""
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import DasConfig, LpsaConfig, ModelConfig, TernaryConfig
from repro.core import das, twd
from repro.core.perfmodel import CPU_HOST, kernel_cost
from repro.kernels import autotune, ops, ref
from repro.models import model as MD
from repro.models.ternary_linear import export_tlin, tlin_apply, tlin_init
from repro.serve import Request, ServeEngine

SCALE = 0.37


@pytest.fixture()
def cache(tmp_path):
    return autotune.AutotuneCache(str(tmp_path / "autotune.json"))


# -------------------------------------------------------------------------
# (a) cache round-trip + zero re-timing
# -------------------------------------------------------------------------

def test_cache_round_trip(cache):
    cfg = autotune.TileConfig("xla_dense_f32dec", block_m=8, block_n=256,
                              block_k=2)
    key = autotune.shape_key("das_ternary_gemm", "cpu", m=4, k=1280, n=512,
                             keep=16, block=32)
    cache.put(key, cfg, 123.4)
    reloaded = autotune.AutotuneCache(cache.path)
    assert reloaded.get(key) == cfg
    assert reloaded.entries[key]["us"] == 123.4
    with open(cache.path) as f:
        assert json.load(f)["version"] == 1


def test_cache_corrupt_file_is_empty(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text("{not json")
    assert autotune.AutotuneCache(str(p)).entries == {}


def test_tune_hit_does_zero_timed_runs(cache):
    dims = dict(m=2, k=320, n=128, keep=16, block=32)
    cfg = autotune.tune("das_ternary_gemm", backend="cpu", cache=cache,
                        budget=2, iters=1, **dims)
    assert cache.timed_runs > 0
    fresh = autotune.AutotuneCache(cache.path)     # reload from disk
    cfg2 = autotune.tune("das_ternary_gemm", backend="cpu", cache=fresh,
                         budget=2, iters=1, **dims)
    assert cfg2 == cfg
    assert fresh.timed_runs == 0


def test_lookup_is_pure_and_deterministic(cache):
    dims = dict(m=4, k=640, n=256, keep=16, block=32)
    a = autotune.lookup("das_ternary_gemm", backend="cpu", cache=cache, **dims)
    b = autotune.lookup("das_ternary_gemm", backend="cpu", cache=cache, **dims)
    assert a == b
    assert cache.timed_runs == 0 and cache.entries == {}   # never persists


def test_shape_key_order_independent():
    assert autotune.shape_key("op", "cpu", m=1, k=2) == \
        autotune.shape_key("op", "cpu", k=2, m=1)


# -------------------------------------------------------------------------
# (b) perfmodel ranking vs reality
# -------------------------------------------------------------------------

def test_perfmodel_orders_cpu_impls():
    """The documented XLA-CPU facts, as the model must rank them:
    masked-dense decode-GEMMs beat the gather path (gathers run ~15x below
    streaming bandwidth), and the f32dec strided decode beats the plain
    int unpack (no materialized digit stack)."""
    dims = dict(m=4, k=1280, n=512, keep=16, block=32)
    c = {impl: kernel_cost(CPU_HOST, "das_ternary_gemm", impl, **dims)
         for impl in ("xla_dense_f32dec", "xla_dense_plain", "xla_gather")}
    assert c["xla_dense_f32dec"] < c["xla_dense_plain"] < c["xla_gather"]
    d = {impl: kernel_cost(CPU_HOST, "ternary_gemm", impl, m=4, k=1280,
                           n=512, keep=0, block=0)
         for impl in ("xla_f32dec", "xla_plain")}
    assert d["xla_f32dec"] < d["xla_plain"]


def test_tuned_winner_among_model_top_ranked(cache):
    """Timed confirmation picks from the perfmodel's top `budget` — i.e. the
    analytic ranking and the measurement agree on the winner's bracket."""
    dims = dict(m=4, k=640, n=256, keep=16, block=32)
    budget = 2
    ranked = sorted(
        autotune.candidates("das_ternary_gemm", "cpu", **dims),
        key=lambda c: kernel_cost(CPU_HOST, "das_ternary_gemm", c.impl,
                                  block_m=c.block_m, block_n=c.block_n,
                                  block_k=c.block_k, **dims))
    won = autotune.tune("das_ternary_gemm", backend="cpu", cache=cache,
                        budget=budget, iters=2, **dims)
    assert won in ranked[:budget]


def test_candidates_feasibility():
    # unaligned K: no pallas tiles, no gather, but masked-dense still covers
    cands = autotune.candidates("das_ternary_gemm", "cpu", m=2, k=5460,
                                n=128, keep=16, block=32)
    impls = {c.impl for c in cands}
    assert "xla_dense_f32dec" in impls and "xla_dense_plain" in impls
    assert "xla_gather" not in impls and "pallas" not in impls
    # interpret backend enumerates only emulated Pallas tiles
    cands = autotune.candidates("das_ternary_gemm", "interpret", m=2, k=320,
                                n=128, keep=16, block=32)
    assert cands and all(c.impl == "interpret" for c in cands)
    # infeasible everywhere -> empty -> lookup returns the ref sentinel
    assert autotune.candidates("ternary_gemm", "interpret", m=2, k=321,
                               n=128, keep=0, block=0) == []
    cfg = autotune.lookup("ternary_gemm", backend="interpret",
                          cache=autotune.AutotuneCache("/nonexistent/x.json"),
                          m=2, k=321, n=128, keep=0, block=0)
    assert cfg.impl == "ref"


# -------------------------------------------------------------------------
# (c) compiled / tuned / interpret / ref parity
# -------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 5),
       st.sampled_from([320, 640]), st.sampled_from([128, 256]),
       st.sampled_from([8, 16, 32]))
def test_gemm_impl_parity_hypothesis(seed, m, k, n, keep):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    trits = rng.integers(-1, 2, size=(k, n)).astype(np.int8)
    packed = jnp.asarray(twd.pack_ternary(trits))
    want = np.asarray(ref.ternary_gemm_packed_ref(x, packed, SCALE, k))
    for impl in ("xla_f32dec", "xla_plain", "interpret"):
        got = np.asarray(autotune.run_gemm(
            x, packed, SCALE, cfg=autotune.TileConfig(impl, 4, 128, 1)))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4,
                                   err_msg=impl)
    ca = das.das_compact(x, block_size=32, keep=keep)
    want = np.asarray(ref.das_ternary_gemm_ref(ca.values, ca.indices, packed,
                                               SCALE, k))
    for impl in ("xla_dense_f32dec", "xla_dense_plain", "xla_gather",
                 "interpret"):
        got = np.asarray(autotune.run_das_gemm(
            ca.values, ca.indices, packed, SCALE, keep=keep, block=32,
            cfg=autotune.TileConfig(impl, 2, 128, 1)))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4,
                                   err_msg=impl)


def test_compiled_mode_matches_ref(rng):
    """`compiled` probes the backend: on CPU it must transparently run the
    Pallas kernels under interpret=True and agree with the reference."""
    m, k, n = 3, 640, 256
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    trits = rng.integers(-1, 2, size=(k, n)).astype(np.int8)
    packed = jnp.asarray(twd.pack_ternary(trits))
    want = np.asarray(ops.ternary_gemm(x, packed, SCALE, mode="ref"))
    got = np.asarray(ops.ternary_gemm(x, packed, SCALE, mode="compiled"))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_tuned_tlin_matches_ref_any_k(rng, tmp_path, monkeypatch):
    """Tuned dispatch covers K the Pallas modes cannot tile (5460 = bitnet
    d_ff: not slab-aligned, not block-divisible) without falling back."""
    monkeypatch.setenv("TENET_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    autotune.reset_default_cache()
    try:
        tc = TernaryConfig(das=DasConfig(32, 16))
        for k in (320, 5460):
            p = export_tlin(tlin_init(jax.random.PRNGKey(0), k, 128), tc)
            x = jnp.asarray(rng.standard_normal((2, k)), jnp.float32)
            a = np.asarray(tlin_apply(p, x, tc, kernel_mode="tuned"))
            b = np.asarray(tlin_apply(p, x, tc, kernel_mode="ref"))
            np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-4)
    finally:
        autotune.reset_default_cache()


# -------------------------------------------------------------------------
# (d) fallback accounting
# -------------------------------------------------------------------------

def test_fallback_warns_once_counts_every_time(rng):
    tc = TernaryConfig(das=DasConfig(32, 16))
    p = export_tlin(tlin_init(jax.random.PRNGKey(0), 64, 48), tc)
    x = jnp.asarray(rng.standard_normal((2, 64)), jnp.float32)
    ops.reset_fallbacks()
    try:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            tlin_apply(p, x, tc, kernel_mode="interpret")
            tlin_apply(p, x, tc, kernel_mode="interpret")
        relevant = [m for m in w if "kernel fallback" in str(m.message)]
        assert len(relevant) == 1                      # once per shape
        counts = ops.fallback_counts()
        assert sum(c for (op, _), c in counts.items()
                   if op == "ternary_gemm") == 2       # every occurrence
        # ref mode is an intentional choice, never a counted fallback
        ops.reset_fallbacks()
        tlin_apply(p, x, tc, kernel_mode="ref")
        assert ops.fallback_counts() == {}
    finally:
        ops.reset_fallbacks()


# -------------------------------------------------------------------------
# (e) serve engine: tuned == ref tokens, second warmup is free
# -------------------------------------------------------------------------

TUNED_CFG = ModelConfig(
    name="tiny-tuned", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
    ternary=TernaryConfig(das=DasConfig(16, 8)),
    lpsa=LpsaConfig(sink=4, window=12, chunk=8),
    dtype="float32", remat=False, scan_layers=False,
)


@pytest.mark.slow
def test_serve_engine_tuned_matches_ref_and_warmup_cached(tmp_path,
                                                          monkeypatch):
    monkeypatch.setenv("TENET_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    autotune.reset_default_cache()
    try:
        params = MD.init_params(jax.random.PRNGKey(0), TUNED_CFG)
        sparams = MD.export_serving(params, TUNED_CFG)
        rng = np.random.default_rng(0)
        trace = [Request(uid=i, prompt=np.asarray(
                             rng.integers(0, TUNED_CFG.vocab, pl), np.int32),
                         max_new_tokens=4, arrival=0)
                 for i, pl in enumerate((9, 16))]
        outs, engines = {}, {}
        for mode in ("ref", "tuned"):
            eng = ServeEngine(TUNED_CFG, sparams, max_slots=2, max_len=64,
                              seed=0, kernel_mode=mode)
            for r in trace:
                eng.submit(r)
            outs[mode] = eng.run()
            engines[mode] = eng
        for uid in outs["ref"]:
            np.testing.assert_array_equal(outs["ref"][uid].tokens,
                                          outs["tuned"][uid].tokens)
        assert engines["tuned"].stats.autotune_timed_runs > 0
        # second engine over identical shapes: warm cache, ZERO timed runs
        autotune.reset_default_cache()     # fresh object, same on-disk file
        eng2 = ServeEngine(TUNED_CFG, sparams, max_slots=2, max_len=64,
                           seed=0, kernel_mode="tuned")
        assert eng2.stats.autotune_timed_runs == 0
        for r in trace:
            eng2.submit(r)
        outs2 = eng2.run()
        for uid in outs["ref"]:
            np.testing.assert_array_equal(outs["ref"][uid].tokens,
                                          outs2[uid].tokens)
    finally:
        autotune.reset_default_cache()
