"""Analytic roofline model (launch/analytic.py): orderings and invariants."""
import dataclasses

from repro.configs import get_config
from repro.configs.shapes import shape_by_name
from repro.launch.analytic import cell_analytic


def _cfg(fmt):
    cfg = get_config("kimi-k2-1t-a32b")
    return dataclasses.replace(cfg, ternary=dataclasses.replace(
        cfg.ternary, serve_format=fmt))


def test_weight_format_ordering_decode():
    """bf16 > int8 > packed memory terms for decode (the TWD claim)."""
    shape = shape_by_name("decode_32k")
    b = {f: cell_analytic(_cfg(f), shape, 256).hbm_bytes_per_dev
         for f in ("bf16", "int8", "packed")}
    assert b["bf16"] > b["int8"] > b["packed"]
    # weight stream shrinks ~5x int8 -> packed (cache is common)
    assert (b["int8"] - b["packed"]) > 2 * b["packed"]


def test_train_collective_dominates_small_dense():
    cfg = get_config("stablelm-1.6b")
    a = cell_analytic(cfg, shape_by_name("train_4k"), 256)
    tc, tm, tl = a.terms()
    assert tl > tc and tl > tm  # TP-16 all-reduce wall (EXPERIMENTS cell C)


def test_all_terms_positive_all_cells():
    from repro.configs import ARCH_MODULES
    from repro.configs.shapes import SHAPES
    for arch in list(ARCH_MODULES)[:10]:
        for shape in SHAPES:
            a = cell_analytic(get_config(arch), shape, 256)
            assert a.flops_per_dev > 0
            assert a.hbm_bytes_per_dev > 0
            assert a.coll_bytes_per_dev >= 0


def test_remat_costs_flops():
    cfg = get_config("gemma3-1b")
    on = cell_analytic(cfg, shape_by_name("train_4k"), 256)
    off = cell_analytic(dataclasses.replace(cfg, remat=False),
                        shape_by_name("train_4k"), 256)
    assert on.flops_per_dev > off.flops_per_dev
