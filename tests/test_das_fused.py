"""Fused DAS->ternary GEMM serving path (Sec. III-C/D/E composition).

(a) kernel parity: `das_ternary_gemm` (interpret mode) vs the
    `das_gemm_ref` gather oracle on TWD-decoded weights AND the
    `stl_matmul_ref` LUT-pipeline oracle on densified activations —
    sweeping batch, keep (incl. the keep==block dense fallback), DAS block,
    and K/N tile edges;
(b) dispatch: `ops.fused_das_ok` admissibility + `tlin_apply` graceful
    fallback to the reference path on kernel-incompatible shapes;
(c) engine integration: `ServeEngine` produces bitwise-identical token
    streams with `kernel_mode="interpret"` (fused packed datapath) and
    `kernel_mode="ref"` (densifying reference) on a slab-aligned model.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import DasConfig, LpsaConfig, ModelConfig, TernaryConfig
from repro.core import das, stl, twd
from repro.kernels import ops, ref
from repro.models import model as MD
from repro.models.ternary_linear import tlin_apply, tlin_compact, tlin_init, \
    export_tlin
from repro.serve import Request, ServeEngine

SCALE = 0.37


def _fused_case(rng, m, k, n, keep, block, mode):
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    trits = rng.integers(-1, 2, size=(k, n)).astype(np.int8)
    packed = jnp.asarray(twd.pack_ternary(trits))
    assert packed.shape[0] * twd.TRITS_PER_BYTE == k  # slab-aligned, no pad
    ca = das.das_compact(x, block_size=block, keep=keep)
    y = np.asarray(ops.das_ternary_gemm(ca.values, ca.indices, packed, SCALE,
                                        keep=keep, block=block, mode=mode))
    return x, trits, packed, ca, y


# -------------------------------------------------------------------------
# (a) kernel vs oracles
# -------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n,keep,block", [
    (1, 320, 128, 16, 32),     # GEMV shape, single slab
    (4, 640, 256, 8, 32),      # decode batch, 2 slabs
    (3, 320, 384, 32, 32),     # keep == block: dense fallback
    (8, 960, 512, 24, 32),     # multi-tile N
    (2, 320, 130, 16, 32),     # N not lane-aligned (bn degrades)
    (5, 640, 128, 16, 16),     # non-default DAS block
    (7, 320, 256, 1, 32),      # extreme sparsity keep=1
])
def test_fused_kernel_matches_oracles(rng, m, k, n, keep, block):
    x, trits, packed, ca, y = _fused_case(rng, m, k, n, keep, block,
                                          "interpret")
    # oracle 1: TWD decode + per-row gather GEMM
    r1 = np.asarray(ref.das_ternary_gemm_ref(ca.values, ca.indices, packed,
                                             SCALE, k))
    # oracle 2: STL LUT pipeline on mask-densified activations (ties the
    # fused kernel to the paper's core semantics end-to-end)
    xs = das.das_apply(x, das.das_mask(x, block_size=block, keep=keep))
    r2 = np.asarray(stl.stl_matmul_ref(xs, jnp.asarray(trits))) * SCALE
    np.testing.assert_allclose(y, r1, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(y, r2, rtol=1e-5, atol=1e-4)


def test_fused_ref_dispatch_matches_interpret(rng):
    m, k, n, keep, block = 3, 640, 256, 16, 32
    _, _, packed, ca, y_i = _fused_case(rng, m, k, n, keep, block, "interpret")
    y_r = np.asarray(ops.das_ternary_gemm(ca.values, ca.indices, packed,
                                          SCALE, keep=keep, block=block,
                                          mode="ref"))
    np.testing.assert_allclose(y_i, y_r, rtol=1e-5, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 6),
       st.sampled_from([320, 640]), st.sampled_from([128, 256, 320]),
       st.sampled_from([1, 8, 16, 31, 32]))
def test_fused_kernel_hypothesis(seed, m, k, n, keep):
    rng = np.random.default_rng(seed)
    _, _, packed, ca, y = _fused_case(rng, m, k, n, keep, 32, "interpret")
    r = np.asarray(ref.das_ternary_gemm_ref(ca.values, ca.indices, packed,
                                            SCALE, k))
    np.testing.assert_allclose(y, r, rtol=1e-5, atol=1e-4)


# -------------------------------------------------------------------------
# (b) dispatch predicates + fallback
# -------------------------------------------------------------------------

def test_fused_das_ok_admissibility():
    d32 = DasConfig(32, 16)
    assert ops.fused_das_ok(320, 64, d32)
    assert ops.fused_das_ok(640, 128, d32)
    assert not ops.fused_das_ok(320, 64, None)          # DAS off
    assert not ops.fused_das_ok(64, 16, d32)            # K not slab-tiled
    assert not ops.fused_das_ok(320, 80, d32)           # padded packed rows
    assert not ops.fused_das_ok(320, 64, DasConfig(48, 24))  # 48 !| 320


def test_tlin_fallback_on_unaligned_shapes(rng):
    """Kernel modes must degrade to the exact reference path, not raise."""
    tc = TernaryConfig(das=DasConfig(32, 16))
    p = export_tlin(tlin_init(jax.random.PRNGKey(0), 64, 48), tc)
    x = jnp.asarray(rng.standard_normal((2, 64)), jnp.float32)
    a = np.asarray(tlin_apply(p, x, tc, kernel_mode="interpret"))
    b = np.asarray(tlin_apply(p, x, tc, kernel_mode="ref"))
    np.testing.assert_array_equal(a, b)
    assert tlin_compact(x, tc, p, kernel_mode="interpret") is None


def test_tlin_shared_compaction_identical(rng):
    """Precomputed compaction (qkv/gate-in sharing) is bit-identical."""
    tc = TernaryConfig(das=DasConfig(32, 16))
    p = export_tlin(tlin_init(jax.random.PRNGKey(1), 320, 160), tc)
    x = jnp.asarray(rng.standard_normal((2, 3, 320)), jnp.float32)
    ca = tlin_compact(x, tc, p, kernel_mode="interpret")
    assert ca is not None
    y0 = np.asarray(tlin_apply(p, x, tc, kernel_mode="interpret"))
    y1 = np.asarray(tlin_apply(p, x, tc, kernel_mode="interpret", ca=ca))
    np.testing.assert_array_equal(y0, y1)
    # and the fused result agrees with the densifying reference
    yr = np.asarray(tlin_apply(p, x, tc, kernel_mode="ref"))
    np.testing.assert_allclose(y0, yr, rtol=1e-5, atol=1e-4)


# -------------------------------------------------------------------------
# (c) serve engine: fused (interpret) == dense (ref) token streams
# -------------------------------------------------------------------------

# every ternary-linear input dim is a multiple of the 320-trit TWD slab
# (d_model = q_dim = d_ff = 320), so EVERY packed layer takes the fused path
FUSED_CFG = ModelConfig(
    name="tiny-fused", family="dense", n_layers=2, d_model=320, n_heads=4,
    n_kv_heads=2, head_dim=80, d_ff=320, vocab=256,
    ternary=TernaryConfig(das=DasConfig(32, 16)),
    lpsa=LpsaConfig(sink=4, window=12, chunk=8),
    dtype="float32", remat=False, scan_layers=False,
)


def _fused_trace(seed=0):
    rng = np.random.default_rng(seed)
    spec = [(9, 3, 0), (16, 3, 1)]   # tail-fed and pack-aligned prompts
    return [Request(uid=i, prompt=np.asarray(
                        rng.integers(0, FUSED_CFG.vocab, p), np.int32),
                    max_new_tokens=g, arrival=a)
            for i, (p, g, a) in enumerate(spec)]


@pytest.mark.slow
def test_serve_engine_fused_matches_ref_tokens():
    params = MD.init_params(jax.random.PRNGKey(0), FUSED_CFG)
    sparams = MD.export_serving(params, FUSED_CFG)
    outs = {}
    for mode in ("ref", "interpret"):
        eng = ServeEngine(FUSED_CFG, sparams, max_slots=2, max_len=64,
                          seed=0, kernel_mode=mode)
        for r in _fused_trace():
            eng.submit(r)
        outs[mode] = eng.run()
    for uid in outs["ref"]:
        np.testing.assert_array_equal(outs["ref"][uid].tokens,
                                      outs["interpret"][uid].tokens)
