"""SPMD serving + elastic recovery.

In-process (single device): the "sharded" GSPMD-safe kernel path is
bitwise-identical to "ref" at the token level; an injected WorkerFailure
mid-decode triggers snapshot -> rebuild -> replay and every in-flight
request still finishes with the same tokens (dense and paged layouts);
the telemetry stream records the reshard.

Subprocess (8 virtual CPU devices, slow): a Topology(dp=2, tp=2) engine
produces bitwise-identical tokens to the single-device engine on a
staggered trace, and an injected failure that loses two devices shrinks
the mesh (tp preserved), replays, and still matches.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.distributed.fault import FaultInjector
from repro.launch.serve import build_engine
from repro.models.transformer import Runtime
from repro.serve import Request, ServeConfig


def _trace(cfg, n=4, prompt_len=24, gen=8, stagger=2, temperature=0.0):
    rng = np.random.default_rng(7)
    return [Request(uid=i,
                    prompt=np.asarray(rng.integers(0, cfg.vocab, (prompt_len,)),
                                      np.int32),
                    max_new_tokens=gen, temperature=temperature,
                    arrival=i * stagger)
            for i in range(n)]


def _run(cfg, kernel_mode, *, layout="auto", injector=None, lost=0,
         telemetry_path=None, gen=8):
    sc = ServeConfig(max_slots=4, max_len=32, layout=layout,
                     page_size=8 if layout == "paged" else 16)
    eng = build_engine(cfg, Runtime(kernel_mode=kernel_mode), config=sc)
    if injector is not None:
        eng.fault_injector = injector
        eng.fault_lost_devices = lost
    if telemetry_path is not None:
        from repro.serve.metrics import Telemetry
        Telemetry(engine=eng, jsonl_path=telemetry_path)
    for r in _trace(cfg, gen=gen):
        eng.submit(r)
    return eng, eng.run()


@pytest.fixture(scope="module")
def cfg():
    return reduced(get_config("bitnet-1.3b"))


def _tokens(results):
    return {uid: results[uid].tokens.tolist() for uid in results}


def test_sharded_kernel_mode_matches_ref(cfg):
    _, ref = _run(cfg, "ref")
    _, sh = _run(cfg, "sharded")
    assert _tokens(ref) == _tokens(sh)


@pytest.mark.parametrize("layout", ["auto", "paged"])
def test_inplace_recovery_replays_all_requests(cfg, layout, tmp_path):
    _, ref = _run(cfg, "ref", layout=layout)
    path = str(tmp_path / "telemetry.jsonl")
    eng, got = _run(cfg, "ref", layout=layout,
                    injector=FaultInjector(fail_at=(3,)),
                    telemetry_path=path)
    assert _tokens(got) == _tokens(ref)          # replay is bitwise
    assert eng.stats.reshards == 1
    assert eng.stats.recovery_seconds > 0
    lines = [json.loads(l) for l in open(path)]
    resh = [l for l in lines if l["type"] == "reshard"]
    assert len(resh) == 1 and resh[0]["in_flight_replayed"] >= 1


def test_recovery_mid_stream_is_repeatable(cfg):
    # two separate failures: both recoveries replay cleanly
    _, ref = _run(cfg, "ref", gen=12)
    eng, got = _run(cfg, "ref", gen=12,
                    injector=FaultInjector(fail_at=(2, 9)))
    assert _tokens(got) == _tokens(ref)
    assert eng.stats.reshards == 2


SCRIPT = r"""
import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
import jax
import numpy as np

if jax.device_count() != 8:
    print("DEVICE-COUNT-SKIP", jax.device_count(), jax.default_backend())
    raise SystemExit(0)

from repro.configs import get_config, reduced
from repro.distributed.fault import FaultInjector
from repro.distributed.plan import Topology
from repro.launch.serve import build_engine
from repro.models.transformer import Runtime
from repro.serve import Request, ServeConfig

cfg = reduced(get_config("bitnet-1.3b"))

def trace(n=4):
    rng = np.random.default_rng(7)
    return [Request(uid=i,
                    prompt=np.asarray(rng.integers(0, cfg.vocab, (24,)),
                                      np.int32),
                    max_new_tokens=8, temperature=0.0, arrival=i * 2)
            for i in range(n)]

def run(topology=None, injector=None, lost=0):
    sc = ServeConfig(max_slots=4, max_len=32, topology=topology)
    eng = build_engine(cfg, Runtime(kernel_mode="sharded"), config=sc)
    if injector is not None:
        eng.fault_injector = injector
        eng.fault_lost_devices = lost
    for r in trace():
        eng.submit(r)
    results = eng.run()
    return eng, {u: results[u].tokens.tolist() for u in results}

_, ref = run()
_, tp = run(Topology(dp=2, tp=2))
assert tp == ref, (tp, ref)
print("OK sharded-parity")

eng, rec = run(Topology(dp=2, tp=2), FaultInjector(fail_at=(3,)), lost=2)
assert rec == ref, (rec, ref)
assert eng.stats.reshards == 1, eng.stats.reshards
assert eng.topology == Topology(dp=1, tp=2), eng.topology  # tp preserved
assert len(rec) == 4
print("OK elastic-recovery", eng.stats.recovery_seconds)
print("ALL-SHARDED-OK")
"""


@pytest.mark.slow
def test_sharded_serving_8dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       timeout=900)
    if "DEVICE-COUNT-SKIP" in r.stdout:
        pytest.skip("runner cannot provide 8 virtual CPU devices: "
                    + r.stdout.strip().splitlines()[-1])
    assert "ALL-SHARDED-OK" in r.stdout, r.stdout + "\n" + r.stderr
