"""Optional-hypothesis shim: property tests run when hypothesis is
installed and skip cleanly (instead of killing collection) when not.

Usage in a test module:

    from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

With hypothesis present these are the real objects; without it, `given`
replaces the test with a zero-arg skipper and `st`/`settings` are inert
placeholders so module-level decorators still evaluate.
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for hypothesis.strategies: any call returns None."""
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
