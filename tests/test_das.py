"""DAS block Top-K sparsity (Sec. III-C): exactness + optimality properties.

Property tests skip (via the hypothesis_compat shim) when hypothesis is
not installed; the deterministic exactness tests always run so tier-1
stays green in a bare environment.
"""
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import das


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 32, 64]),
       st.integers(1, 16))
def test_mask_counts(seed, block, keep):
    keep = min(keep, block)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((3, block * 4)), jnp.float32)
    m = np.asarray(das.das_mask(x, block_size=block, keep=keep))
    counts = m.reshape(3, 4, block).sum(-1)
    assert np.all(counts == keep)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_mask_keeps_largest(seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((2, 64)).astype(np.float32)
    m = np.asarray(das.das_mask(jnp.asarray(x), block_size=32, keep=16))
    for r in range(2):
        for b in range(2):
            blk = np.abs(x[r, b * 32:(b + 1) * 32])
            mb = m[r, b * 32:(b + 1) * 32]
            # kept magnitude sum == top-16 magnitude sum (optimality)
            assert np.isclose(blk[mb].sum(), np.sort(blk)[-16:].sum(),
                              rtol=1e-6)


def test_compact_matches_masked_dense(rng):
    x = jnp.asarray(rng.standard_normal((3, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 16)), jnp.float32)
    m = das.das_mask(x, block_size=32, keep=16)
    ca = das.das_compact(x, block_size=32, keep=16)
    ref = np.asarray(das.das_apply(x, m)) @ np.asarray(w)
    out = np.asarray(das.das_gemm_ref(ca, w))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_compact_indices_sorted_and_valid(rng):
    x = jnp.asarray(rng.standard_normal((2, 96)), jnp.float32)
    ca = das.das_compact(x, block_size=32, keep=8)
    idx = np.asarray(ca.indices).reshape(2, 3, 8)
    for b in range(3):
        blk = idx[:, b]
        assert np.all((blk >= b * 32) & (blk < (b + 1) * 32))
        assert np.all(np.diff(blk, axis=-1) > 0)


def test_gradient_flows_through_kept_only(rng):
    import jax
    x = jnp.asarray(rng.standard_normal((1, 64)), jnp.float32)
    m = das.das_mask(x, block_size=32, keep=16)
    g = jax.grad(lambda x_: jnp.sum(das.das_apply(x_, m)))(x)
    assert np.array_equal(np.asarray(g) != 0, np.asarray(m))
