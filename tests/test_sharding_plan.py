"""Topology/ShardingPlan API (distributed/plan.py): zoo-wide spec
coverage with the replicated fall-through set pinned per arch, topology
algebra (shrink/dp_axes/mesh errors), validation failures, and the
legacy-shim deprecation contract.
"""
import re
import warnings

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_MODULES, get_config, reduced
from repro.distributed import sharding
from repro.distributed.plan import ShardingPlan, Topology
from repro.models import model as MD

ARCHS = sorted(ARCH_MODULES)

# Intentionally replicated >=2D serving leaves under tp=2, per arch
# (pattern-collapsed: [i] matches any layer index).  Anything new showing
# up here means a param-spec rule gap — extend sharding.py's rule sets,
# don't just re-pin.
REPLICATED_2D = {
    "gla-1.3b": {"layers/tail/[i]/gla/wa1"},
    "kimi-k2-1t-a32b": {"layers/tail/[i]/moe/router"},
    "qwen3-moe-30b-a3b": {"layers/tail/[i]/moe/router"},
    "rwkv6-3b": {
        "layers/tail/[i]/rwkv/cr/packed", "layers/tail/[i]/rwkv/mix_c",
        "layers/tail/[i]/rwkv/mix_t", "layers/tail/[i]/rwkv/u",
        "layers/tail/[i]/rwkv/w_decay1", "layers/tail/[i]/rwkv/wr/packed",
    },
    "zamba2-2.7b": {
        "layers/tail/[i]/mamba/conv", "layers/tail/[i]/mamba/wb",
        "layers/tail/[i]/mamba/wc", "layers/tail/[i]/mamba/wdt",
    },
}


def _serving_tree(cfg):
    return jax.eval_shape(lambda: MD.export_serving(
        MD.init_params(jax.random.PRNGKey(0), cfg), cfg))


@pytest.mark.parametrize("arch", ARCHS)
def test_plan_covers_zoo_serving_tree(arch):
    cfg = reduced(get_config(arch))
    plan = ShardingPlan.for_config(cfg, Topology(tp=2), validate=False)
    tree = _serving_tree(cfg)
    # every leaf resolved: structure match is what _iter_spec_leaves checks
    n = sum(1 for _ in plan._iter_spec_leaves(tree))
    assert n == len(jax.tree.leaves(tree)) > 0
    rep = {re.sub(r"\[\d+\]", "[i]", p) for p in plan.replicated_leaves(tree)}
    assert rep == REPLICATED_2D.get(arch, set()), rep
    # describe() renders one row per leaf without error
    assert len(plan.describe(tree).splitlines()) >= n


@pytest.mark.parametrize("arch", ["bitnet-1.3b", "qwen3-moe-30b-a3b",
                                  "rwkv6-3b", "zamba2-2.7b"])
def test_plan_caches_cover_slot_state(arch):
    from repro.models.transformer import Runtime
    import jax.numpy as jnp
    cfg = reduced(get_config(arch))
    topo = Topology(dp=2, tp=2)
    caches = jax.eval_shape(lambda: MD.init_caches(
        None, cfg, 4, 64, Runtime(), jnp.float32))
    plan = ShardingPlan.for_config(cfg, topo, validate=False)
    plan = plan.with_caches(caches, batch=4)
    specs = jax.tree.leaves(plan.caches, is_leaf=lambda x: isinstance(x, P))
    assert len(specs) == len(jax.tree.leaves(caches))
    # the slot/batch dim rides the dp axes somewhere in the tree
    assert any("data" in str(s) for s in specs)


def test_topology_algebra():
    t = Topology(dp=2, tp=2)
    assert t.axis_names == ("data", "model") and t.shape == (2, 2)
    assert t.n_devices == 4 and t.dp_extent == 2
    assert t.dp_axes_for(4) == ("data",) and t.dp_axes_for(3) == ()
    tp2 = Topology(dp=16, tp=16, pods=2)
    assert tp2.axis_names == ("pod", "data", "model")
    assert tp2.batch_spec() == P(("pod", "data"))
    assert tp2.batch_spec(sequence_sharded=True) == P(None, ("pod", "data"))
    assert Topology.production(multi_pod=True) == tp2
    with pytest.raises(ValueError):
        Topology(dp=0)


def test_topology_shrink_prefers_tp():
    # tp survives whole when it divides the survivor count; dp never grows
    assert Topology(dp=2, tp=2).shrink(2) == Topology(dp=1, tp=2)
    assert Topology(dp=4, tp=2).shrink(7) == Topology(dp=4, tp=1)
    assert Topology(dp=2, tp=2, pods=2).shrink(4) == Topology(dp=2, tp=2)
    assert Topology(dp=1, tp=1).shrink(0) == Topology(dp=1, tp=1)


def test_build_mesh_actionable_error():
    need = len(jax.devices()) + 1
    with pytest.raises(RuntimeError,
                       match=f"host_platform_device_count={need}"):
        Topology(dp=need).build_mesh()
    # and from_mesh round-trips a buildable topology
    t = Topology()
    assert Topology.from_mesh(t.build_mesh()) == t


def test_validate_reports_indivisible_leaves():
    cfg = reduced(get_config("bitnet-1.3b"))
    with pytest.raises(ValueError, match="not.*divisible|divisible"):
        ShardingPlan.for_config(cfg, Topology(tp=7))
    # and the permissive path still resolves specs
    plan = ShardingPlan.for_config(cfg, Topology(tp=7), validate=False)
    assert plan.params is not None


def test_legacy_shims_warn_once():
    cfg = reduced(get_config("bitnet-1.3b"))
    tree = jax.eval_shape(lambda: MD.init_params(jax.random.PRNGKey(0), cfg))
    sharding._DEPRECATION_WARNED.clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        s1 = sharding.param_specs(tree)
        s2 = sharding.param_specs(tree)   # second call: no second warning
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1 and "ShardingPlan" in str(dep[0].message)
    # shim output matches the plan API bit-for-bit
    assert s1 == s2 == ShardingPlan.for_tree(tree, validate=False).params
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert sharding.batch_spec(True) == \
            Topology(pods=2, dp=1).batch_spec()
    assert any(issubclass(w.category, DeprecationWarning) for w in rec)


def test_zero1_unsharded_summary_warning():
    cfg = reduced(get_config("bitnet-1.3b"))
    tree = jax.eval_shape(lambda: MD.init_params(jax.random.PRNGKey(0), cfg))
    plan = ShardingPlan.for_tree(tree, Topology(dp=7), validate=False)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        z = plan.zero1(tree)
    msgs = [str(w.message) for w in rec if "stay unsharded" in str(w.message)]
    assert len(msgs) == 1 and "data=7" in msgs[0]
    # nothing divides by 7 in the reduced config -> all moments unsharded
    assert all("data" not in str(s) for s in
               jax.tree.leaves(z, is_leaf=lambda x: isinstance(x, P)))
