"""Per-arch smoke tests: REDUCED configs of every assigned architecture run
one forward and one train step on CPU — output shapes + no NaNs (the full
configs are exercised only via launch/dryrun.py, ShapeDtypeStruct-only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_MODULES, get_config, reduced
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.optim import adamw

ARCHS = list(ARCH_MODULES)
RT = Runtime()


def _inputs(cfg, B=2, S=32, seed=1):
    if MD.uses_embeds(cfg):
        x = jax.random.normal(jax.random.PRNGKey(seed), (B, S, cfg.d_model),
                              jnp.float32)
    else:
        x = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(seed + 1), (B, S), 0,
                                cfg.vocab)
    return x, labels


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch))
    p = MD.init_params(jax.random.PRNGKey(0), cfg)
    x, _ = _inputs(cfg)
    logits = MD.forward(p, cfg, x, RT)
    assert logits.shape == (2, 32, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab]).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite_and_updates(arch):
    cfg = reduced(get_config(arch))
    p = MD.init_params(jax.random.PRNGKey(0), cfg)
    x, labels = _inputs(cfg)
    batch = {"inputs": x, "labels": labels}

    def lf(pp):
        return MD.loss_fn(pp, cfg, batch, RT)
    (loss, _), grads = jax.value_and_grad(lf, has_aux=True)(p)
    assert np.isfinite(float(loss))
    gn = adamw.global_norm(grads)
    assert np.isfinite(float(gn)) and float(gn) > 0
    opt = adamw.adamw_init(p)
    p2, _, _ = adamw.adamw_step(p, grads, opt, lr=1e-3)
    # at least one parameter moved
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "zamba2-2.7b",
                                  "gemma3-1b", "rwkv6-3b"])
def test_scan_layers_matches_unrolled(arch):
    """scan-over-groups must be numerically identical to the python loop."""
    import dataclasses
    cfg = reduced(get_config(arch))
    plen = len(cfg.layer_pattern)
    cfg_scan = dataclasses.replace(cfg, n_layers=2 * plen, scan_layers=True)
    cfg_flat = dataclasses.replace(cfg, n_layers=2 * plen, scan_layers=False)
    p_scan = MD.init_params(jax.random.PRNGKey(0), cfg_scan)
    x, _ = _inputs(cfg)
    a = MD.forward(p_scan, cfg_scan, x, RT)
    # rebuild flat params from the stacked tree
    stacked = p_scan["layers"]["stacked"]
    tail = []
    for g in range(2):
        for j in range(plen):
            tail.append(jax.tree.map(lambda t: t[g], stacked[j]))
    p_flat = dict(p_scan)
    p_flat["layers"] = {"stacked": None, "tail": tuple(tail),
                        "shared": p_scan["layers"]["shared"]}
    b = MD.forward(p_flat, cfg_flat, x, RT)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)
