"""Continuous-batching serving: per-sequence caches + engine invariance.

(a) per-sequence attn_write/attn_read reduces to the old shared-t
    behaviour when all sequences are in lock-step;
(b) engine integration: staggered requests with different prompt lengths
    produce tokens bitwise identical to running each request alone
    (batch invariance), in both `full` and `ring` (LPSA) cache modes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DasConfig, LpsaConfig, ModelConfig, TernaryConfig
from repro.core import lpsa
from repro.models import attention as A
from repro.models import kvcache as KV
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.serve import FifoScheduler, Request, ServeEngine, sample_token

CFG = ModelConfig(
    name="tiny-serve", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
    ternary=TernaryConfig(das=DasConfig(16, 8)),
    lpsa=LpsaConfig(sink=4, window=12, chunk=8),
    dtype="float32", remat=False, scan_layers=False,
)


@pytest.fixture(scope="module")
def sparams():
    params = MD.init_params(jax.random.PRNGKey(0), CFG)
    return MD.export_serving(params, CFG)


# -------------------------------------------------------------------------
# (a) cache layer: per-sequence t == shared t in lock-step
# -------------------------------------------------------------------------

@pytest.mark.parametrize("ring", [False, True])
def test_attn_write_lockstep_matches_shared_t(rng, ring):
    B, S, Hkv, D = 3, 20, CFG.n_kv_heads, CFG.head_dim_
    sink, window = 4, 12
    init = (KV.init_attn_ring(CFG, B, sink, window, jnp.float32) if ring
            else KV.init_attn_full(CFG, B, S, jnp.float32))
    shared, perseq = init, init
    for t in range(16):
        k = jnp.asarray(rng.standard_normal((B, 1, Hkv, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, 1, Hkv, D)), jnp.float32)
        shared = KV.attn_write(shared, k, v, jnp.array(t), sink=sink,
                               window=window, ring=ring)
        perseq = KV.attn_write(perseq, k, v, jnp.full((B,), t), sink=sink,
                               window=window, ring=ring)
    for name in ("k", "v", "pos"):
        np.testing.assert_array_equal(np.asarray(shared[name]),
                                      np.asarray(perseq[name]))
    assert shared["pos"].shape[0] == B  # position map is per-sequence


def test_attn_write_per_sequence_positions(rng):
    """Sequences at different depths land in their own ring slots."""
    B, Hkv, D = 2, CFG.n_kv_heads, CFG.head_dim_
    sink, window = 4, 12
    cache = KV.init_attn_ring(CFG, B, sink, window, jnp.float32)
    t = jnp.asarray([2, 30])          # row 0 in sink range, row 1 deep decode
    k = jnp.asarray(rng.standard_normal((B, 1, Hkv, D)), jnp.float32)
    cache = KV.attn_write(cache, k, k, t, sink=sink, window=window, ring=True)
    pos = np.asarray(cache["pos"])
    slot0 = int(lpsa.decode_slot(jnp.array(2), sink, window))
    slot1 = int(lpsa.decode_slot(jnp.array(30), sink, window))
    assert pos[0, slot0] == 2 and pos[1, slot1] == 30
    assert pos[1, slot0] == -1        # row 1 untouched at row 0's slot


@pytest.mark.parametrize("serve_sparse", [True, False])
def test_attn_decode_vector_t_matches_scalar(rng, serve_sparse):
    B = 2
    rt = Runtime(serve_sparse=serve_sparse)
    ap = A.attn_init(jax.random.PRNGKey(3), CFG)
    sink, window = A.kind_sink_window(CFG, "attn", serve_sparse)
    cache_s = (KV.init_attn_ring(CFG, B, sink, window, jnp.float32)
               if sink < A.FULL_SINK
               else KV.init_attn_full(CFG, B, 24, jnp.float32))
    cache_v = cache_s
    for t in range(8):
        x = jnp.asarray(rng.standard_normal((B, 1, CFG.d_model)), jnp.float32)
        y_s, cache_s = A.attn_decode(ap, CFG, x, cache_s, jnp.array(t), "attn",
                                     serve_sparse=serve_sparse)
        y_v, cache_v = A.attn_decode(ap, CFG, x, cache_v, jnp.full((B,), t),
                                     "attn", serve_sparse=serve_sparse)
        np.testing.assert_array_equal(np.asarray(y_s), np.asarray(y_v))
    _ = rt


# -------------------------------------------------------------------------
# (b) engine integration: batch invariance under staggered admission
# -------------------------------------------------------------------------

def _trace(seed=0):
    rng = np.random.default_rng(seed)
    # prompt 11: shorter than one pack (pure tail feed); 19: pack + tail;
    # 16: exactly pack-aligned (first token from prefill logits)
    spec = [(11, 6, 0, 0.0), (19, 5, 3, 0.9), (16, 4, 4, 0.0)]
    return [Request(uid=i,
                    prompt=np.asarray(rng.integers(0, CFG.vocab, p), np.int32),
                    max_new_tokens=g, arrival=a, temperature=tmp)
            for i, (p, g, a, tmp) in enumerate(spec)]


@pytest.mark.parametrize("serve_sparse", [True, False],
                         ids=["ring", "full"])
def test_engine_batch_invariance(sparams, serve_sparse):
    rt = Runtime(serve_sparse=serve_sparse)
    trace = _trace()
    eng = ServeEngine(CFG, sparams, rt, max_slots=2, max_len=64, seed=0)
    for r in trace:
        eng.submit(r)
    joint = eng.run()
    assert set(joint) == {r.uid for r in trace}
    for r in trace:
        solo_eng = ServeEngine(CFG, sparams, rt, max_slots=2, max_len=64,
                               seed=0)
        solo_eng.submit(r)
        solo = solo_eng.run()[r.uid]
        np.testing.assert_array_equal(solo.tokens, joint[r.uid].tokens)
        assert len(joint[r.uid].tokens) == r.max_new_tokens


def test_engine_admits_mid_decode(sparams):
    """A request arriving later joins while earlier slots keep decoding."""
    trace = _trace()
    eng = ServeEngine(CFG, sparams, Runtime(), max_slots=2, max_len=64)
    for r in trace:
        eng.submit(r)
    results = eng.run()
    late = results[2]
    assert late.admit_vtime >= trace[2].arrival > 0
    assert late.admitted_with_active > 0   # other slots were mid-generation
    # overlap: it was admitted strictly before the last earlier request done
    assert late.admit_vtime < max(results[0].finish_vtime,
                                  results[1].finish_vtime)
    assert eng.stats.slot_utilization > 0.5


def test_engine_eos_frees_slot(sparams):
    """EOS termination frees the slot early (fewer tokens than max)."""
    rng = np.random.default_rng(1)
    prompt = np.asarray(rng.integers(0, CFG.vocab, 11), np.int32)
    eng = ServeEngine(CFG, sparams, Runtime(), max_slots=1, max_len=64)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=50))
    free_run = eng.run()[0]
    eos = int(free_run.tokens[2])     # pretend the 3rd sampled id is EOS
    eng2 = ServeEngine(CFG, sparams, Runtime(), max_slots=1, max_len=64)
    eng2.submit(Request(uid=0, prompt=prompt, max_new_tokens=50, eos_id=eos))
    stopped = eng2.run()[0]
    assert len(stopped.tokens) == 3 and stopped.tokens[-1] == eos


def test_engine_rejects_bad_requests_and_resets(sparams):
    eng = ServeEngine(CFG, sparams, Runtime(), max_slots=1, max_len=64)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(uid=0, prompt=np.zeros((0,), np.int32),
                           max_new_tokens=4))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(Request(uid=0, prompt=np.zeros((4,), np.int32),
                           max_new_tokens=0))
    eng.submit(Request(uid=7, prompt=np.zeros((4,), np.int32),
                       max_new_tokens=1))
    with pytest.raises(ValueError, match="already in flight"):
        eng.submit(Request(uid=7, prompt=np.zeros((6,), np.int32),
                           max_new_tokens=1))
    eng.run()
    rt_full = Runtime(serve_sparse=False)
    eng_full = ServeEngine(CFG, sparams, rt_full, max_slots=1, max_len=16)
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng_full.submit(Request(uid=0, prompt=np.zeros((12,), np.int32),
                                max_new_tokens=8))
    # reset_clock: only valid drained; zeroes vtime/stats, keeps jit caches
    req = _trace()[0]
    eng.submit(req)
    eng.run()
    assert eng.vtime > 0
    eng.reset_clock()
    assert eng.vtime == 0 and eng.stats.decode_steps == 0
    eng.submit(req)
    with pytest.raises(RuntimeError, match="non-drained"):
        eng.reset_clock()
    assert len(eng.run()[req.uid].tokens) == req.max_new_tokens


def test_wave_policy_matches_tokens_but_serializes(sparams):
    """Lock-step baseline: same per-request tokens, later finish times."""
    trace = _trace()
    cont = ServeEngine(CFG, sparams, Runtime(), max_slots=2, max_len=64)
    wave = ServeEngine(CFG, sparams, Runtime(), max_slots=2, max_len=64,
                       policy="wave")
    for r in trace:
        cont.submit(r)
        wave.submit(r)
    rc, rw = cont.run(), wave.run()
    for r in trace:
        np.testing.assert_array_equal(rc[r.uid].tokens, rw[r.uid].tokens)
    assert wave.stats.decode_steps >= cont.stats.decode_steps


# -------------------------------------------------------------------------
# scheduler + sampler units
# -------------------------------------------------------------------------

def test_scheduler_priority_then_arrival():
    s = FifoScheduler()
    mk = lambda uid, arr, pri=0: Request(uid=uid, prompt=np.zeros(1, np.int32),
                                         max_new_tokens=1, arrival=arr,
                                         priority=pri)
    s.add(mk(0, 5))        # future-dated
    s.add(mk(1, 0))
    s.add(mk(2, 0, pri=-1))
    assert s.pop_ready(0).uid == 2    # best priority first
    assert s.pop_ready(0).uid == 1    # future-dated uid=0 never blocks
    assert s.pop_ready(0) is None
    assert s.next_arrival() == 5
    assert s.pop_ready(5).uid == 0
    assert len(s) == 0


def test_sampler_modes(rng):
    logits = jnp.asarray(rng.standard_normal(64), jnp.float32)
    key = jax.random.PRNGKey(0)
    greedy = sample_token(logits, key, jnp.float32(0.0))
    assert int(greedy) == int(jnp.argmax(logits))
    # top-k restricts support to the k best ids
    top4 = set(np.asarray(jax.lax.top_k(logits, 4)[1]).tolist())
    draws = {int(sample_token(logits, jax.random.PRNGKey(i),
                              jnp.float32(5.0), top_k=4)) for i in range(32)}
    assert draws <= top4 and len(draws) > 1
    # deterministic per key
    a = sample_token(logits, jax.random.PRNGKey(7), jnp.float32(1.0))
    b = sample_token(logits, jax.random.PRNGKey(7), jnp.float32(1.0))
    assert int(a) == int(b)
