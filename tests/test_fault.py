"""Fault tolerance: injected failures must not change the final parameters."""
import jax.numpy as jnp
import numpy as np

from repro.distributed import fault
from repro.checkpoint import restore_checkpoint, save_checkpoint


def _deterministic_step(state, step):
    # state := state * 1.01 + f(step)  — order-sensitive, so replay bugs show
    return state * 1.01 + jnp.float32(step % 7)


def _run(tmp, fail_at=()):
    saved = {}

    def save(state, step):
        saved["latest"] = (np.asarray(state).copy(), step)
        save_checkpoint(tmp, step, {"s": state})

    def restore():
        tree, step = restore_checkpoint(tmp)
        return tree["s"], step

    state, stats = fault.resilient_loop(
        init_state=jnp.float32(1.0), step_fn=_deterministic_step, n_steps=25,
        save_fn=save, restore_fn=restore, ckpt_every=5,
        injector=fault.FaultInjector(fail_at))
    return np.asarray(state), stats


def test_failures_are_transparent(tmp_path):
    clean, _ = _run(str(tmp_path / "a"))
    faulty, stats = _run(str(tmp_path / "b"), fail_at=(3, 11, 17, 24))
    assert stats["restarts"] == 4
    np.testing.assert_allclose(clean, faulty, rtol=0, atol=0)


def test_straggler_monitor_flags():
    mon = fault.StragglerMonitor(warmup=3, k=3.0)
    for i in range(20):
        mon.observe(i, 0.1)
    assert mon.observe(20, 5.0)          # 50x slower step flagged
    assert len(mon.flagged) == 1


def test_data_pipeline_replay():
    from repro.data.pipeline import SyntheticLM
    src = SyntheticLM(vocab=128, seq_len=16, batch=4, seed=3)
    a = [src.batch_at(s)["inputs"] for s in range(5)]
    b = [src.batch_at(s)["inputs"] for s in range(5)]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
