"""Optimizer: AdamW math, clipping, schedules, accumulation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, grad as gradlib, schedule


def test_adamw_matches_manual():
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.1, 0.2])}
    st = adamw.adamw_init(p)
    p2, st2, _ = adamw.adamw_step(p, g, st, lr=0.1, b1=0.9, b2=0.95,
                                  weight_decay=0.0, clip_norm=None)
    m = 0.1 * np.array([0.1, 0.2])
    v = 0.05 * np.array([0.1, 0.2]) ** 2
    mh, vh = m / 0.1, v / 0.05
    want = np.array([1.0, -2.0]) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-5)


def test_clipping_bounds_update():
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    st = adamw.adamw_init(p)
    _, _, info = adamw.adamw_step(p, g, st, lr=1.0, clip_norm=1.0)
    assert float(info["grad_norm"]) == 200.0  # pre-clip norm reported


def test_wsd_phases():
    kw = dict(peak_lr=1.0, warmup=10, total=100)
    assert float(schedule.wsd_schedule(5, **kw)) == 0.5          # warmup
    assert float(schedule.wsd_schedule(50, **kw)) == 1.0         # stable
    assert float(schedule.wsd_schedule(99, **kw)) < 0.05         # decay tail


def test_accumulation_matches_full_batch():
    W = jax.random.normal(jax.random.PRNGKey(0), (8, 8))

    def lf(w, batch):
        return jnp.mean((batch["x"] @ w - batch["y"]) ** 2), {}

    x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    y = jax.random.normal(jax.random.PRNGKey(2), (16, 8))
    full_g = jax.grad(lambda w: lf(w, {"x": x, "y": y})[0])(W)
    micro = {"x": x.reshape(4, 4, 8), "y": y.reshape(4, 4, 8)}
    loss, acc_g, _ = gradlib.accumulate_grads(lf, W, micro, 4)
    np.testing.assert_allclose(np.asarray(acc_g), np.asarray(full_g),
                               rtol=1e-5, atol=1e-6)
