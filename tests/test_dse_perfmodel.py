"""Perf/power model + DSE (Secs. IV-D, V): paper-claim reproduction bounds."""
import numpy as np

from repro.core import dse, ipj, perfmodel as pm


def test_ipj_definition():
    assert np.isclose(ipj.ipj(100.0, 10.0, 5.0), 2.0)


def test_twd_cuts_decode_bytes():
    m = pm.LLAMA_1B3
    naive = pm.stage_cost(m, "decode", 2048, pm.TenetOpt.naive_int8(),
                          decode_tokens=64)
    twd = pm.stage_cost(m, "decode", 2048, pm.TenetOpt.twd(),
                        decode_tokens=64)
    red = 1 - twd.weight_bytes / naive.weight_bytes
    # linear weights alone drop exactly 80% (8b -> 1.6b); the fp16 LM head
    # rides along in weight_bytes, pulling the blended figure to ~72%
    assert 0.70 <= red <= 0.82
    emb = 2 * m.embed_params() * 64  # fp16 head bytes x decode_tokens
    lin_red = 1 - (twd.weight_bytes - emb) / (naive.weight_bytes - emb)
    assert abs(lin_red - 0.8) < 0.01


def test_paper_decode_memory_reduction():
    """Fig 15: TWD reduces decode-stage memory access ~74.8% vs int8-naive."""
    m = pm.LLAMA_3B
    naive = pm.stage_cost(m, "decode", 2048, pm.TenetOpt.naive_int8(),
                          decode_tokens=128)
    full = pm.stage_cost(m, "decode", 2048, pm.TenetOpt.full(),
                         decode_tokens=128)
    red = 1 - full.bytes / naive.bytes
    assert 0.6 <= red <= 0.85


def test_das_halves_linear_flops():
    m = pm.LLAMA_1B3
    dense = pm.linear_cost(m, 1024, pm.TenetOpt.twd())
    sparse = pm.linear_cost(m, 1024, pm.TenetOpt.twd_das())
    assert np.isclose(sparse.flops_low / dense.flops_low, 0.5)


def test_lpsa_caps_attention():
    m = pm.LLAMA_7B
    full = pm.attention_cost(m, 8192, 1, pm.TenetOpt(lpsa=False),
                             fused_onchip=False)
    sparse = pm.attention_cost(m, 8192, 1, pm.TenetOpt(lpsa=True, tl_sa=1024),
                               fused_onchip=True)
    assert sparse.flops_high < full.flops_high / 7
    assert sparse.act_bytes < full.act_bytes / 3


def test_dse_constraint_enforced():
    cands = dse.dse_grid_search(pm.LLAMA_1B3, "bitnet-1.3b")
    for c in cands:
        assert c.p_l / c.p_h < pm.LLAMA_1B3.d_model / c.tl_sa


def test_dse_prefers_mid_sparsity():
    """S_a=1/2 should beat S_a=1/4 (ppl blowup) and compete with dense."""
    cands = dse.dse_grid_search(pm.LLAMA_3B, "bitnet-3b")
    best = cands[0]
    assert best.s_a >= 0.5


def test_tenet_beats_a100_energy():
    """Fig 13 direction: TENET-ASIC decode energy-efficiency >> A100."""
    m = pm.LLAMA_3B
    opt = pm.TenetOpt.full()
    ten = pm.e2e(m, pm.TENET_ASIC, opt, prefill_tl=512, decode_tokens=512)
    a100 = pm.e2e(m, pm.A100_OPT, pm.TenetOpt.naive_int8(), prefill_tl=512,
                  decode_tokens=512)
    eff_ratio = (a100.energy_j / ten.energy_j)
    assert eff_ratio > 5  # paper: 11.1x vs A100-opt, 21.1x vs naive
