"""Serving == training numerics: prefill + decode reproduces forward.

Exact (<=1e-4) with quantization disabled; loose with ternary+DAS on
(STE rounding / TopK ties flip discretely under 1e-7 noise — inherent to
quantized+sparse models, not a serving bug; see DESIGN.md)."""
import dataclasses
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_MODULES, get_config, reduced
from repro.models import model as MD
from repro.models.transformer import Runtime

RT = Runtime()
B, S, PRE = 2, 32, 16


def _run(cfg):
    p = MD.init_params(jax.random.PRNGKey(0), cfg)
    if MD.uses_embeds(cfg):
        xin = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                                jnp.float32)
        pre, dec = xin[:, :PRE], lambda t: xin[:, t:t + 1]
    else:
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
        xin, pre, dec = toks, toks[:, :PRE], lambda t: toks[:, t]
    full = MD.forward(p, cfg, xin, RT)
    lg, caches = MD.prefill(p, cfg, pre, RT, max_len=S)
    errs = [float(jnp.abs(lg - full[:, PRE - 1]).max())]
    for t in range(PRE, S):
        lg, caches = MD.decode_step(p, cfg, caches, dec(t), jnp.array(t), RT)
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
    return max(errs)


@pytest.mark.parametrize("arch", list(ARCH_MODULES))
def test_exact_without_quantization(arch):
    cfg = reduced(get_config(arch))
    cfg = dataclasses.replace(cfg, ternary=dataclasses.replace(
        cfg.ternary, enabled=False, das=None))
    assert _run(cfg) < 1e-4


@pytest.mark.parametrize("arch", [
    "bitnet-1.3b", "gemma3-1b", "zamba2-2.7b", "rwkv6-3b", "gla-1.3b"])
def test_quantized_close(arch):
    cfg = reduced(get_config(arch))
    assert _run(cfg) < 5e-2  # boundary flips only
