"""Multi-device semantics (8 virtual CPU devices via subprocess):
  * sharded train step == single-device step (pjit correctness)
  * MoE shard_map EP == local dispatch
  * pipeline-parallel forward == plain forward
  * compressed cross-pod psum ~= exact psum (int8 tolerance)
Run in a subprocess so the forced device count can't leak into other tests.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
# the parent test injects --xla_force_host_platform_device_count=8; keep a
# belt-and-braces append here for anyone running the script standalone
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.models import model as MD
from repro.models import moe as MOE
from repro.models.transformer import Runtime
from repro.optim import adamw

if jax.device_count() != 8:
    # non-CPU backends ignore the host-platform flag; nothing to test here
    print("DEVICE-COUNT-SKIP", jax.device_count(), jax.default_backend())
    raise SystemExit(0)

# ---- 1. sharded train step == single device ------------------------------
cfg = reduced(get_config("bitnet-1.3b"))
cfg = dataclasses.replace(cfg, ternary=dataclasses.replace(cfg.ternary, das=None))
mesh = jax.make_mesh((4, 2), ("data", "model"))
p = MD.init_params(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
batch = {"inputs": toks, "labels": jnp.roll(toks, -1, 1)}
rt1 = Runtime()
def lf(pp, bb):
    return MD.loss_fn(pp, cfg, bb, rt1)[0]
l_single = jax.jit(lf)(p, batch)
from repro.distributed.plan import ShardingPlan, Topology
pspec = ShardingPlan.for_tree(p, Topology.from_mesh(mesh),
                              validate=False).params
ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda x: isinstance(x, P))
bspec = {"inputs": NamedSharding(mesh, P("data")), "labels": NamedSharding(mesh, P("data"))}
with mesh:
    l_shard = jax.jit(lf, in_shardings=(ns(pspec), bspec))(p, batch)
np.testing.assert_allclose(float(l_single), float(l_shard), rtol=2e-5)
print("OK sharded-loss")

# gradients too
g1 = jax.jit(jax.grad(lf))(p, batch)
with mesh:
    g2 = jax.jit(jax.grad(lf), in_shardings=(ns(pspec), bspec))(p, batch)
err = max(float(jnp.abs(a - b).max()) for a, b in
          zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
assert err < 2e-4, err
print("OK sharded-grads", err)

# ---- 2. MoE shard_map EP == local ----------------------------------------
cfgm = reduced(get_config("qwen3-moe-30b-a3b"))
pm = MOE.moe_init(jax.random.PRNGKey(0), cfgm)
x = jax.random.normal(jax.random.PRNGKey(2), (8, 16, cfgm.d_model)) * 0.5
y_local = MOE.moe_apply(pm, cfgm, x)
y_ep = jax.jit(lambda pp, xx: MOE.moe_apply(
    pp, cfgm, xx, mesh=mesh, dp_axes=("data",), ep_axis="model"))(pm, x)
np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_ep),
                           rtol=5e-4, atol=5e-4)
print("OK moe-ep")

# ---- 3. pipeline parallel == plain ---------------------------------------
from repro.distributed.pipeline import pipeline_apply
mesh_pp = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
W = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 16)) * 0.3  # 2 stages
def stage_fn(w, xb):
    return jnp.tanh(xb @ w)
xb = jax.random.normal(jax.random.PRNGKey(4), (8, 16))
y_ref = stage_fn(W[1], stage_fn(W[0], xb))
y_pp = pipeline_apply(stage_fn, W, xb, mesh=mesh_pp, axis="pod",
                      n_microbatches=4)
np.testing.assert_allclose(np.asarray(y_pp), np.asarray(y_ref),
                           rtol=1e-5, atol=1e-5)
print("OK pipeline")

# PP backward
def loss_pp(w):
    return jnp.sum(pipeline_apply(stage_fn, w, xb, mesh=mesh_pp, axis="pod",
                                  n_microbatches=4) ** 2)
def loss_ref(w):
    return jnp.sum(stage_fn(w[1], stage_fn(w[0], xb)) ** 2)
gpp = jax.grad(loss_pp)(W)
gref = jax.grad(loss_ref)(W)
np.testing.assert_allclose(np.asarray(gpp), np.asarray(gref), rtol=1e-4,
                           atol=1e-4)
print("OK pipeline-grad")

# ---- 4. compressed cross-pod grad exchange --------------------------------
from repro.optim.grad import compressed_crosspod_mean, zeros_error
g = {"w": jax.random.normal(jax.random.PRNGKey(5), (64, 64))}
err0 = zeros_error(g)
mean, err1 = compressed_crosspod_mean(g, err0, mesh_pp, pod_axis="pod")
# Topology is accepted in place of a mesh (built internally)
mean_t, _ = compressed_crosspod_mean(g, err0, Topology(dp=2, tp=2, pods=2),
                                     pod_axis="pod")
np.testing.assert_allclose(np.asarray(mean_t["w"]), np.asarray(mean["w"]),
                           rtol=1e-6, atol=1e-6)
# identical grads on both pods -> mean == dequantized value, small error
rel = float(jnp.abs(mean["w"] - g["w"]).max() / jnp.abs(g["w"]).max())
assert rel < 0.02, rel
assert float(jnp.abs(err1["w"]).max()) > 0  # error feedback captured residual
print("OK compressed-psum", rel)
print("ALL-MULTIDEVICE-OK")
"""


@pytest.mark.slow
def test_multidevice_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       timeout=900)
    if "DEVICE-COUNT-SKIP" in r.stdout:
        pytest.skip("runner cannot provide 8 virtual CPU devices: "
                    + r.stdout.strip().splitlines()[-1])
    assert "ALL-MULTIDEVICE-OK" in r.stdout, r.stdout + "\n" + r.stderr
