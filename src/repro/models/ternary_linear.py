"""TernaryLinear — the paper's Ternary Linear module as a first-class layer.

One logical layer, three physical representations:

  * **master**  {"w": f32/bf16}          — training / QAT: STE ternary
    fake-quant + A8 activation fake-quant + DAS mask (Eq. 1 end-to-end).
  * **packed**  {"packed": u8, "scale"}  — serving: base-3 TWD bytes; the
    matmul goes through kernels/ops (Pallas fused decode on TPU, jnp
    reference elsewhere).
  * **trits**   {"trits": i8, "scale"}   — the paper's "naive INT8/INT2"
    ablation points (weights resident unpacked).

`export_serving` converts master -> packed/trits/bf16 offline, exactly like
the paper's offline weight encoder feeding the TWD ROM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TernaryConfig
from repro.core import das as das_lib
from repro.core import ternary as tq
from repro.core import twd
from repro.kernels import ops

__all__ = ["tlin_init", "tlin_apply", "export_tlin"]


def tlin_init(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32,
              scale: float | None = None) -> dict:
    s = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * s
    return {"w": w.astype(dtype)}


def _das_maybe(x: jax.Array, tc: TernaryConfig) -> jax.Array:
    if tc.das is None:
        return x
    mask = das_lib.das_mask(x, block_size=tc.das.block, keep=tc.das.keep)
    return das_lib.das_apply(x, mask)


def tlin_apply(p: dict, x: jax.Array, tc: TernaryConfig, *,
               kernel_mode: str = "ref") -> jax.Array:
    """Apply the ternary linear in whatever representation `p` carries."""
    if not tc.enabled:
        w = p["w"] if "w" in p else p["w_hp"]
        return jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))

    if "w" in p:  # --- training / QAT path (differentiable) ----------------
        xs = _das_maybe(x, tc)
        xq = tq.int8_fake_quant(xs)
        wq = tq.ternary_fake_quant(p["w"])
        return jnp.einsum("...k,kn->...n", xq, wq.astype(xq.dtype))

    # --- serving paths ------------------------------------------------------
    xs = _das_maybe(x, tc)
    scale = p["scale"]
    if "packed" in p:
        k = xs.shape[-1]
        lead = xs.shape[:-1]
        x2 = xs.reshape(-1, k)
        if kernel_mode in ("pallas", "interpret"):
            y = ops.ternary_gemm(x2, p["packed"], scale, mode=kernel_mode)
        else:
            w = twd.unpack_ternary_arith(p["packed"], k)
            y = jnp.einsum("mk,kn->mn", x2.astype(jnp.float32),
                           w.astype(jnp.float32)) * scale
        n = y.shape[-1]
        return y.reshape(*lead, n).astype(x.dtype)
    if "trits" in p:
        w = p["trits"].astype(x.dtype) * scale.astype(x.dtype)
        return jnp.einsum("...k,kn->...n", xs, w)
    raise KeyError(f"unrecognized ternary-linear params: {sorted(p)}")


def export_tlin(p: dict, tc: TernaryConfig) -> dict:
    """Master -> serving representation (offline encoder for the TWD path)."""
    if "w" not in p:
        return p
    if not tc.enabled:
        return {"w_hp": p["w"]}
    tw = tq.ternary_quantize(p["w"])
    if tc.serve_format == "packed":
        return {"packed": twd.pack_ternary(tw.values, row_align=16),
                "scale": tw.scale}
    if tc.serve_format == "int8":
        return {"trits": tw.values, "scale": tw.scale}
    if tc.serve_format == "bf16":
        return {"trits": tw.values.astype(jnp.bfloat16).astype(jnp.int8),
                "scale": tw.scale}
    raise ValueError(tc.serve_format)
