"""TernaryLinear — the paper's Ternary Linear module as a first-class layer.

One logical layer, three physical representations:

  * **master**  {"w": f32/bf16}          — training / QAT: STE ternary
    fake-quant + A8 activation fake-quant + DAS mask (Eq. 1 end-to-end).
  * **packed**  {"packed": u8, "scale"}  — serving: base-3 TWD bytes; the
    matmul goes through kernels/ops (Pallas fused decode on TPU, jnp
    reference elsewhere).
  * **trits**   {"trits": i8, "scale"}   — the paper's "naive INT8/INT2"
    ablation points (weights resident unpacked).

`export_serving` converts master -> packed/trits/bf16 offline, exactly like
the paper's offline weight encoder feeding the TWD ROM.

Serving dispatch for the packed representation (the paper's Sec. III-C/D/E
composition):

  * DAS on + kernel mode + slab-aligned shapes  ->  `ops.das_ternary_gemm`:
    activations are block-compacted once (`tlin_compact`, shareable across
    sibling projections of the same input) and routed *compacted* against
    the base-3 packed weights — dense activations never round-trip HBM.
  * kernel mode but DAS off / unaligned shapes  ->  `ops.ternary_gemm`
    (fused TWD decode, dense activations).
  * otherwise (or shapes incompatible with any kernel) -> pure-jnp
    reference: densified DAS mask + unpack + einsum.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TernaryConfig
from repro.core import das as das_lib
from repro.core import ternary as tq
from repro.core import twd
from repro.kernels import ops

__all__ = ["tlin_init", "tlin_apply", "tlin_compact", "export_tlin",
           "MaskedActivation"]


class MaskedActivation(NamedTuple):
    """Densified DAS-masked activations — the tuned-mode shared prep when the
    autotuned impl is one of the ``xla_dense_*`` decode-GEMMs (a rank-compare
    mask is ~5x cheaper than the top-k compaction on XLA-CPU).  Produced by
    `tlin_compact`, consumed by `tlin_apply` via ``ca=`` like its compacted
    sibling `core.das.CompactActivation`."""

    x: jax.Array   # (..., K) f32, dropped lanes zeroed


def tlin_init(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32,
              scale: float | None = None) -> dict:
    s = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * s
    return {"w": w.astype(dtype)}


def _das_maybe(x: jax.Array, tc: TernaryConfig) -> jax.Array:
    if tc.das is None:
        return x
    mask = das_lib.das_mask(x, block_size=tc.das.block, keep=tc.das.keep)
    return das_lib.das_apply(x, mask)


def tlin_compact(x: jax.Array, tc: TernaryConfig,
                 p: dict | None = None, *, kernel_mode: str = "ref"):
    """Block-compact `x` for the fused DAS serving path, or None.

    Returns a `CompactActivation` only when a layer with params `p` (any
    sibling sharing the same input works — pass one of them) would actually
    take the fused path; callers projecting the same `x` through several
    packed linears (q/k/v, gate/in) compute this once and pass it to each
    `tlin_apply` via ``ca=``.
    """
    if tc.das is None or not tc.enabled:
        return None
    if not ops.kernel_wanted(kernel_mode):
        return None
    if kernel_mode == "tuned":
        # the prep representation follows the tuned impl: xla_dense_* wants a
        # densified mask (shared across siblings), everything else compacted
        if p is None or "packed" not in p:
            return None
        from repro.kernels import autotune, xla_gemm
        k = x.shape[-1]
        m = 1
        for s in x.shape[:-1]:
            m *= s
        cfg = autotune.lookup("das_ternary_gemm", m=m, k=k,
                              n=p["packed"].shape[1], keep=tc.das.keep,
                              block=tc.das.block)
        if cfg.impl.startswith("xla_dense"):
            return MaskedActivation(
                xla_gemm.masked_dense(x, keep=tc.das.keep,
                                      block=tc.das.block))
        if cfg.impl == "ref" or k % tc.das.block:
            return None
        return das_lib.das_compact(x, block_size=tc.das.block,
                                   keep=tc.das.keep)
    if p is not None:
        if "packed" not in p:
            return None
        if not ops.fused_das_ok(x.shape[-1], p["packed"].shape[0], tc.das):
            return None
    return das_lib.das_compact(x, block_size=tc.das.block, keep=tc.das.keep)


def _apply_packed_tuned(p: dict, x2: jax.Array, tc: TernaryConfig,
                        ca) -> jax.Array:
    """Tuned-mode serving matmul: per-shape impl from the autotune cache.

    Trace-safe — `autotune.lookup` only reads the cache (perfmodel ranking on
    a miss); tuning happened eagerly in the ServeEngine warmup.  Unlike the
    Pallas modes this covers *any* K: the ``xla_dense_*`` impls mask with a
    dense tail, so e.g. bitnet's d_ff=5460 stays on a tuned path.
    """
    from repro.kernels import autotune, xla_gemm
    m, k = x2.shape
    scale = p["scale"]
    n = p["packed"].shape[1]
    if tc.das is None:
        return ops.ternary_gemm(x2, p["packed"], scale, mode="tuned")
    cfg = autotune.lookup("das_ternary_gemm", m=m, k=k, n=n,
                          keep=tc.das.keep, block=tc.das.block)
    if cfg.impl.startswith("xla_dense"):
        xs = ca.x.reshape(-1, k) if isinstance(ca, MaskedActivation) \
            else xla_gemm.masked_dense(x2, keep=tc.das.keep,
                                       block=tc.das.block)
        return xla_gemm.decode_matmul(xs, p["packed"], scale, impl=cfg.impl)
    if cfg.impl == "ref" or k % tc.das.block:
        ops.note_fallback("das_ternary_gemm", (m, k, n),
                          "no tuned candidate for this shape")
        xs = _das_maybe(x2, tc)
        w = twd.unpack_ternary_arith(p["packed"], k)
        return (xs.astype(jnp.float32) @ w.astype(jnp.float32)) * scale
    if not isinstance(ca, das_lib.CompactActivation):
        ca = das_lib.das_compact(x2, block_size=tc.das.block,
                                 keep=tc.das.keep)
    kc = ca.values.shape[-1]
    return autotune.run_das_gemm(
        ca.values.reshape(-1, kc), ca.indices.reshape(-1, kc), p["packed"],
        scale, keep=tc.das.keep, block=tc.das.block, cfg=cfg)


def _unpack5(packed: jax.Array) -> jax.Array:
    """Slice-free base-3 decode: (Kp, N) u8 -> (5*Kp, N) i8 trits.

    ``twd.unpack_ternary_arith`` ends with a ``flat[:k]`` slice to drop the
    pack padding, which forces GSPMD to gather a K-sharded slab before
    slicing.  The sharded path instead decodes the *full* padded slab —
    padding bytes decode to 0-trits, so zero-padding the activations to
    5*Kp (see `_apply_packed_sharded`) makes the padded contraction exact.
    Pure reshape/arithmetic, so a "model"-sharded dim stays sharded.
    """
    digits = [(packed // jnp.uint8(3 ** i)) % 3 for i in range(twd.TRITS_PER_BYTE)]
    stacked = jnp.stack(digits, axis=1)            # (Kp, 5, N)
    flat = stacked.reshape(-1, packed.shape[-1])   # (5*Kp, N)
    return flat.astype(jnp.int8) - 1


def _apply_packed_sharded(p: dict, x2: jax.Array,
                          tc: TernaryConfig) -> jax.Array:
    """GSPMD-friendly packed matmul for the "sharded" kernel mode.

    Column-parallel layers shard N ("model" on packed dim 1) with no
    communication; row-parallel layers shard packed K (dim 0), and the
    zero-padded contraction below reduces with exactly one all-reduce —
    the Megatron one-collective-per-block-half pattern.  No Pallas, no
    dynamic slicing: every op here propagates a NamedSharding.
    """
    k = x2.shape[-1]
    xs = _das_maybe(x2, tc).astype(jnp.float32)
    w = _unpack5(p["packed"]).astype(jnp.float32)  # (5*Kp, N), zeros past k
    xp = jnp.pad(xs, ((0, 0), (0, w.shape[0] - k)))
    return (xp @ w) * p["scale"]


def _apply_packed(p: dict, x: jax.Array, tc: TernaryConfig,
                  kernel_mode: str, ca) -> jax.Array:
    """Serving matmul against base-3 packed weights (see module docstring)."""
    k = x.shape[-1]
    lead = x.shape[:-1]
    scale = p["scale"]
    kp = p["packed"].shape[0]
    if kernel_mode == "sharded":
        y = _apply_packed_sharded(p, x.reshape(-1, k), tc)
    elif kernel_mode == "tuned":
        y = _apply_packed_tuned(p, x.reshape(-1, k), tc, ca)
    elif ops.kernel_wanted(kernel_mode) and ops.fused_das_ok(k, kp, tc.das):
        # fused path: compacted activations straight into the kernel
        if ca is None:
            ca = das_lib.das_compact(x, block_size=tc.das.block,
                                     keep=tc.das.keep)
        kc = ca.values.shape[-1]
        y = ops.das_ternary_gemm(
            ca.values.reshape(-1, kc), ca.indices.reshape(-1, kc),
            p["packed"], scale, keep=tc.das.keep, block=tc.das.block,
            mode=kernel_mode)
    elif ops.kernel_wanted(kernel_mode) and ops.packed_gemm_ok(k, kp):
        xs = _das_maybe(x, tc)
        y = ops.ternary_gemm(xs.reshape(-1, k), p["packed"], scale,
                             mode=kernel_mode)
    else:  # shapes a kernel can't tile (or ref mode): pure-jnp reference
        if ops.kernel_wanted(kernel_mode):
            ops.note_fallback("ternary_gemm", (k, p["packed"].shape[1]),
                              f"K={k} not tileable by the {ops.K_SLAB}-trit "
                              f"slab (packed rows {kp})")
        xs = _das_maybe(x, tc)
        w = twd.unpack_ternary_arith(p["packed"], k)
        y = jnp.einsum("mk,kn->mn", xs.reshape(-1, k).astype(jnp.float32),
                       w.astype(jnp.float32)) * scale
    return y.reshape(*lead, y.shape[-1]).astype(x.dtype)


def tlin_apply(p: dict, x: jax.Array, tc: TernaryConfig, *,
               kernel_mode: str = "ref", ca=None) -> jax.Array:
    """Apply the ternary linear in whatever representation `p` carries.

    ``ca`` optionally supplies a precomputed `CompactActivation` of `x`
    (from `tlin_compact`) so sibling projections of one input don't repeat
    the per-block top-k; it is consulted only on the fused packed path.

    ``kernel_mode`` accepts anything ``ops.KernelMode.parse`` does (members,
    canonical names, aliases); unknown modes raise ValueError here, at the
    API edge, instead of silently selecting the reference path downstream.
    """
    kernel_mode = ops.KernelMode.parse(kernel_mode).value
    if not tc.enabled:
        w = p["w"] if "w" in p else p["w_hp"]
        return jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))

    if "w" in p:  # --- training / QAT path (differentiable) ----------------
        xs = _das_maybe(x, tc)
        xq = tq.int8_fake_quant(xs)
        wq = tq.ternary_fake_quant(p["w"])
        return jnp.einsum("...k,kn->...n", xq, wq.astype(xq.dtype))

    # --- serving paths ------------------------------------------------------
    if "packed" in p:
        return _apply_packed(p, x, tc, kernel_mode, ca)
    if "trits" in p:
        xs = _das_maybe(x, tc)
        w = p["trits"].astype(x.dtype) * p["scale"].astype(x.dtype)
        return jnp.einsum("...k,kn->...n", xs, w)
    raise KeyError(f"unrecognized ternary-linear params: {sorted(p)}")


def export_tlin(p: dict, tc: TernaryConfig) -> dict:
    """Master -> serving representation (offline encoder for the TWD path)."""
    if "w" not in p:
        return p
    if not tc.enabled:
        return {"w_hp": p["w"]}
    tw = tq.ternary_quantize(p["w"])
    if tc.serve_format == "packed":
        return {"packed": twd.pack_ternary(tw.values, row_align=16),
                "scale": tw.scale}
    if tc.serve_format == "int8":
        return {"trits": tw.values, "scale": tw.scale}
    if tc.serve_format == "bf16":
        return {"trits": tw.values.astype(jnp.bfloat16).astype(jnp.int8),
                "scale": tw.scale}
    raise ValueError(tc.serve_format)
