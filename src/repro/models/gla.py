"""GLA — Gated Linear Attention block (paper Sec. V-D, ref [61]).

q/k/v projections + a low-rank data-dependent forget gate
alpha_t = sigmoid(x W_a1 W_a2)^{1/tau} per key dim, output gate, and the
chunked linear-attention engine shared with RWKV6.  Ternary + DAS apply to
all projections — the paper's GLA+TQ+DAS configuration (Table III).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.linear_attn import chunked_linear_attn, linear_attn_step
from repro.models.ternary_linear import tlin_apply, tlin_compact, tlin_init

__all__ = ["gla_init", "gla_train", "gla_decode"]

GATE_LORA = 16
TAU = 16.0


def gla_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    h, hd = cfg.n_heads, cfg.head_dim_
    ks = jax.random.split(key, 8)
    return {
        "wq": tlin_init(ks[0], d, h * hd, dtype),
        "wk": tlin_init(ks[1], d, h * hd, dtype),
        "wv": tlin_init(ks[2], d, h * hd, dtype),
        "wg": tlin_init(ks[3], d, h * hd, dtype),
        "wa1": L.dense_init(ks[4], d, GATE_LORA, dtype),
        "wa2": L.dense_init(ks[5], GATE_LORA, h * hd, dtype),
        "ln_x": {"scale": jnp.ones((h * hd,), dtype),
                 "bias": jnp.zeros((h * hd,), dtype)},
        "wo": tlin_init(ks[6], h * hd, d, dtype,
                        scale=(h * hd * 2 * cfg.n_layers) ** -0.5),
    }


def _proj(p, cfg, x, kernel_mode):
    b, l, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim_
    tc = cfg.ternary
    # q/k/v/g share the input: one DAS compaction feeds all four on the
    # fused packed serving path (no-op in training / ref modes)
    ca = tlin_compact(x, tc, p["wq"], kernel_mode=kernel_mode)
    q = tlin_apply(p["wq"], x, tc, kernel_mode=kernel_mode,
                   ca=ca).reshape(b, l, h, hd)
    k = tlin_apply(p["wk"], x, tc, kernel_mode=kernel_mode,
                   ca=ca).reshape(b, l, h, hd)
    v = tlin_apply(p["wv"], x, tc, kernel_mode=kernel_mode,
                   ca=ca).reshape(b, l, h, hd)
    g = tlin_apply(p["wg"], x, tc, kernel_mode=kernel_mode, ca=ca)
    la = jax.nn.log_sigmoid(
        x.astype(jnp.float32) @ p["wa1"].astype(jnp.float32)
        @ p["wa2"].astype(jnp.float32)) / TAU
    return q, k, v, g, la.reshape(b, l, h, hd)


def _out(p, cfg, o, g, kernel_mode):
    h, hd = cfg.n_heads, cfg.head_dim_
    b, l = o.shape[0], o.shape[1]
    of = o.reshape(b, l, h, hd).astype(jnp.float32)
    mu, var = of.mean(-1, keepdims=True), of.var(-1, keepdims=True)
    of = ((of - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(b, l, h * hd)
    of = (of * p["ln_x"]["scale"].astype(jnp.float32)
          + p["ln_x"]["bias"].astype(jnp.float32)).astype(g.dtype)
    y = of * jax.nn.silu(g)
    return tlin_apply(p["wo"], y, cfg.ternary, kernel_mode=kernel_mode)


def gla_train(p: dict, cfg: ModelConfig, x: jax.Array, *,
              kernel_mode: str = "ref", chunk: int = 64,
              s0: jax.Array | None = None):
    q, k, v, g, la = _proj(p, cfg, x, kernel_mode)
    o, s_fin = chunked_linear_attn(q, k, v, la, chunk=chunk, mode="gla", s0=s0)
    return _out(p, cfg, o.reshape(x.shape[0], x.shape[1], -1), g,
                kernel_mode), s_fin


def gla_decode(p: dict, cfg: ModelConfig, x: jax.Array, state: dict, *,
               kernel_mode: str = "ref"):
    q, k, v, g, la = _proj(p, cfg, x, kernel_mode)
    o, s_new = linear_attn_step(q[:, 0], k[:, 0], v[:, 0], la[:, 0],
                                state["s"], mode="gla")
    y = _out(p, cfg, o.reshape(x.shape[0], 1, -1), g, kernel_mode)
    return y, {"s": s_new}
