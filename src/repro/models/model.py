"""Public model API: init / train forward / loss / prefill / decode / export.

`build(cfg)` returns a Model namespace of pure functions for one config.
Inputs:  tokens (B, S) int32, or precomputed embeddings (B, S, D) for the
stub-frontend families (audio/vlm, per the brief).  Training targets are
next-token labels (B, S) with -1 = masked.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import kvcache as KV  # noqa: F401  (re-export convenience)
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import transformer as T
from repro.models.ternary_linear import export_tlin
from repro.models.transformer import Runtime

__all__ = ["Runtime", "init_params", "forward", "loss_fn", "prefill",
           "decode_step", "init_caches", "export_serving", "uses_embeds"]


def uses_embeds(cfg: ModelConfig) -> bool:
    return cfg.frontend != "none"


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    k_e, k_h, k_s = jax.random.split(key, 3)
    p = {
        "embed": L.embed_init(k_e, cfg.vocab_padded, cfg.d_model, dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "layers": T.stack_init(k_s, cfg, dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = L.dense_init(k_h, cfg.d_model, cfg.vocab_padded, dtype,
                                 scale=0.02)
    return p


def _inputs_to_x(p: dict, cfg: ModelConfig, batch_in: jax.Array) -> jax.Array:
    if batch_in.dtype in (jnp.int32, jnp.int64):
        scale = cfg.family == "dense" and cfg.name.startswith("gemma")
        return L.take_embed(p["embed"], batch_in, scale=scale)
    return batch_in  # stub frontend supplies embeddings directly


def _logits(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = L.rmsnorm(p["final_norm"], x)
    if cfg.tie_embeddings:
        lg = L.logits_from_embed(p["embed"], x, cfg.logit_softcap)
    else:
        lg = L.softcap(jnp.einsum("...d,dv->...v", x,
                                  p["head"].astype(x.dtype)).astype(jnp.float32),
                       cfg.logit_softcap)
    if cfg.vocab_padded > cfg.vocab:  # mask padded vocab rows
        lg = lg + jnp.where(jnp.arange(cfg.vocab_padded) < cfg.vocab,
                            0.0, -1e30)
    return lg


def forward(p: dict, cfg: ModelConfig, batch_in: jax.Array,
            rt: Runtime = Runtime()) -> jax.Array:
    """Full-sequence forward -> logits (B, S, V) f32."""
    x = _inputs_to_x(p, cfg, batch_in)
    x = T.stack_train(p["layers"], cfg, x, rt)
    return _logits(p, cfg, x)


def loss_fn(p: dict, cfg: ModelConfig, batch: dict,
            rt: Runtime = Runtime()) -> tuple[jax.Array, dict]:
    """Next-token cross entropy.  batch: {"inputs", "labels"}; labels -1 = pad."""
    logits = forward(p, cfg, batch["inputs"], rt)
    labels = batch["labels"]
    mask = labels >= 0
    lab = jnp.where(mask, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1)
    loss = nll.sum() / denom
    return loss, {"loss": loss, "tokens": denom}


def prefill(p: dict, cfg: ModelConfig, batch_in: jax.Array,
            rt: Runtime = Runtime(), *, max_len: int | None = None):
    """Serving prefill: -> (last-position logits (B, V), caches)."""
    s = batch_in.shape[1]
    max_len = max_len if max_len is not None else s + 1
    x = _inputs_to_x(p, cfg, batch_in)
    x, caches = T.stack_prefill(p["layers"], cfg, x, rt, max_len)
    return _logits(p, cfg, x[:, -1:])[:, 0], caches


def init_caches(p_or_none, cfg: ModelConfig, batch: int, max_len: int,
                rt: Runtime = Runtime(), dtype=jnp.bfloat16, *,
                page_size: int = 0, num_pages: int = 0) -> dict:
    """Decode caches without a prefill pass (dry-run entry point).

    ``page_size > 0`` allocates would-be full attention caches as one shared
    paged arena per layer (kvcache.CacheSpec layout="paged"); decode_step
    then needs a ``page_table``.  Other layouts are unaffected.
    """
    kinds = cfg.layer_kinds()
    plen = len(cfg.layer_pattern)
    n_groups, tail = (divmod(cfg.n_layers, plen) if cfg.scan_layers
                      else (0, cfg.n_layers))
    stacked = None
    if n_groups:
        per_pos = []
        for j, kind in enumerate(cfg.layer_pattern):
            one = T.init_layer_cache(cfg, kind, batch, max_len, rt, dtype,
                                     page_size=page_size, num_pages=num_pages)
            per_pos.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), one))
        stacked = tuple(per_pos)
    tail_caches = tuple(
        T.init_layer_cache(cfg, kinds[n_groups * plen + i], batch, max_len,
                           rt, dtype, page_size=page_size,
                           num_pages=num_pages)
        for i in range(tail))
    return {"stacked": stacked, "tail": tail_caches}


def decode_step(p: dict, cfg: ModelConfig, caches: dict, token_or_embed,
                t, rt: Runtime = Runtime(), page_table=None):
    """One decode step.  t: scalar position (lock-step batch) or (B,)
    per-sequence positions (continuous batching); for paged caches inactive
    rows pass t = -1 and ``page_table`` (B, pages_per_seq) int32 addresses
    the shared arenas.  -> (logits (B, V), new caches)."""
    if token_or_embed.ndim == 1:
        token_or_embed = token_or_embed[:, None]
    x = _inputs_to_x(p, cfg, token_or_embed)
    x, caches = T.stack_decode(p["layers"], cfg, x, caches, t, rt, page_table)
    return _logits(p, cfg, x)[:, 0], caches


def export_serving(p: dict, cfg: ModelConfig) -> dict:
    """Master weights -> serving representation (TWD packing, Sec. III-E).

    Scan-stacked leaves (leading group axis) are exported per-group via vmap
    so per-tensor scales stay per-layer."""
    def conv(tree: Any) -> Any:
        if isinstance(tree, dict):
            if "w" in tree and hasattr(tree["w"], "ndim"):
                if tree["w"].ndim == 2:
                    return export_tlin(tree, cfg.ternary)
                if tree["w"].ndim == 3:      # stacked (G, K, N)
                    return jax.vmap(lambda w: export_tlin({"w": w},
                                                          cfg.ternary))(tree["w"])
            if "experts_gate" in tree:
                if tree["experts_gate"]["w"].ndim == 4:  # stacked (G,E,D,F)
                    return jax.vmap(lambda t: MOE.export_moe(t, cfg))(tree)
                return MOE.export_moe(tree, cfg)
            return {k: conv(v) for k, v in tree.items()}
        if isinstance(tree, tuple):
            return tuple(conv(v) for v in tree)
        return tree
    out = dict(p)
    out["layers"] = conv(p["layers"])
    return out
