"""Chunked gated linear attention — shared engine for GLA and RWKV6.

Recurrence (per head, per key-dim gated decay alpha_t ∈ (0,1]):

    S_t = diag(alpha_t) S_{t-1} + k_t^T v_t
    GLA  : o_t = q_t S_t
    RWKV6: o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)      (bonus on self)

Chunked parallel form (the FLA trick, paper refs [61][62]): within a chunk,
with La = cumsum(log alpha) (per key dim),

    o_t = (q_t ⊙ e^{La_t - d_t}) @ S_in                        (inter)
        + Σ_{s≺t} [(q_t ⊙ e^{La_t - d_t}) · (k_s ⊙ e^{-La_s})] v_s   (intra)
    S_out = diag(e^{La_L}) S_in + Σ_s (k_s ⊙ e^{La_L - La_s})^T v_s

where d_t = log alpha_t for RWKV (S_{t-1} excludes step t's decay) and 0 for
GLA, and ≺ is < for RWKV (self handled by the u bonus) and ≤ for GLA.
La is clamped at CLAMP so e^{-La} stays finite; decays below e^CLAMP are
numerically zero anyway.  The chunk loop is a lax.scan (O(L·c·D) memory);
`linear_attn_step` is the exact single-token decode recurrence.
"""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

__all__ = ["chunked_linear_attn", "linear_attn_step"]

# per-step log-decay floor: keeps the factorized chunk form exact in f32
# (|cumsum| <= chunk * |LOG_A_MIN| => exp(-cumsum) < f32 max) while a decay
# of e^-1.5 per step is already numerically-zero retention within a chunk.
LOG_A_MIN = -1.5


def chunked_linear_attn(q, k, v, log_a, *, chunk: int,
                        mode: Literal["gla", "rwkv"] = "gla",
                        u: jax.Array | None = None,
                        s0: jax.Array | None = None):
    """q,k,v,log_a: (B, L, H, D) (log_a per key dim, <= 0).

    Returns (o (B,L,H,D), S_final (B,H,Dk,Dv)).  u: (H, D) RWKV bonus.
    """
    b, l, h, d = q.shape
    c = min(chunk, l)
    if c * -LOG_A_MIN > 85.0:
        c = max(1, int(85.0 // -LOG_A_MIN))
        while l % c:
            c -= 1
    if l % c:
        raise ValueError(f"L={l} not divisible by chunk={c}")
    n = l // c
    tohead = lambda x: x.reshape(b, n, c, h, d).transpose(1, 0, 3, 2, 4)  # noqa: E731
    qc, kc, vc, lac = map(tohead, (q, k, v, log_a))      # (n, B, H, c, D)

    if s0 is None:
        s0 = jnp.zeros((b, h, d, d), jnp.float32)

    causal = jnp.tril(jnp.ones((c, c), bool), 0 if mode == "gla" else -1)

    def step(s_in, blk):
        qb, kb, vb, la = (x.astype(jnp.float32) for x in blk)
        la = jnp.maximum(la, LOG_A_MIN)
        cla = jnp.cumsum(la, axis=-2)                    # inclusive (B,H,c,D)
        d_t = la if mode == "rwkv" else 0.0
        q_eff = qb * jnp.exp(cla - d_t)
        k_eff = kb * jnp.exp(-cla)
        scores = jnp.einsum("bhtd,bhsd->bhts", q_eff, k_eff)
        scores = jnp.where(causal, scores, 0.0)
        o = jnp.einsum("bhts,bhsd->bhtd", scores, vb)    # intra
        o += jnp.einsum("bhtd,bhde->bhte", q_eff, s_in)  # inter
        if mode == "rwkv" and u is not None:
            diag = jnp.einsum("bhtd,hd,bhtd->bht", qb, u.astype(jnp.float32), kb)
            o += diag[..., None] * vb
        la_end = cla[..., -1:, :]                        # (B,H,1,D)
        k_state = kb * jnp.exp(la_end - cla)
        s_out = jnp.exp(la_end[..., 0, :, None]) * s_in + jnp.einsum(
            "bhtd,bhte->bhde", k_state, vb)
        return s_out, o

    s_fin, oc = jax.lax.scan(step, s0, (qc, kc, vc, lac))
    o = oc.transpose(1, 0, 3, 2, 4).reshape(b, l, h, d)
    return o.astype(q.dtype), s_fin


def linear_attn_step(q, k, v, log_a, s, *, mode: Literal["gla", "rwkv"] = "gla",
                     u: jax.Array | None = None):
    """Exact one-token recurrence.  q,k,v,log_a: (B, H, D); s: (B, H, Dk, Dv)."""
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    a = jnp.exp(jnp.maximum(log_a.astype(jnp.float32), LOG_A_MIN))
    kv = kf[..., :, None] * vf[..., None, :]             # (B,H,Dk,Dv)
    if mode == "rwkv":
        wkv = s + (u.astype(jnp.float32)[None, :, :, None] if u is not None
                   else 1.0) * kv
        o = jnp.einsum("bhd,bhde->bhe", qf, wkv)
        s_new = a[..., None] * s + kv
    else:
        s_new = a[..., None] * s + kv
        o = jnp.einsum("bhd,bhde->bhe", qf, s_new)
    return o.astype(q.dtype), s_new
