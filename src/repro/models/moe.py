"""Mixture-of-Experts FFN with ternary experts and expert parallelism.

Routing: top-k softmax router (fp32) with capacity-bounded, sort-based
dispatch (position-in-expert from a stable argsort — the GShard/Switch
recipe without the O(T·E·C) one-hot dispatch tensor).

Expert parallelism (EP): experts shard on the "model" mesh axis.  Under
`shard_map` each device dispatches its local tokens to *its own* experts
only (out-of-range scatter indices drop the rest), runs the expert FFNs,
and a `psum` over the model axis re-assembles every token's mixture — the
TPU rendition of the all-to-all exchange: tokens never move, only D-wide
partial outputs reduce, which beats a2a whenever top_k ≥ 1 destinations
span shards (see EXPERIMENTS.md §Perf for the measured collective terms).

The same dispatch code runs without a mesh (single-device smoke tests) by
treating the full expert range as local.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoeConfig
from repro.core import ternary as tq
from repro.core import twd
from repro.distributed.sharding import shard_map
from repro.models.ternary_linear import tlin_apply, tlin_compact

__all__ = ["moe_init", "moe_apply", "export_moe", "decode_capacity"]


def moe_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    e: MoeConfig = cfg.moe
    d, f = cfg.d_model, e.d_expert
    ks = jax.random.split(key, 5)

    def w(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    p = {
        "router": w(ks[0], (d, e.n_experts), d ** -0.5),
        "experts_gate": {"w": w(ks[1], (e.n_experts, d, f), d ** -0.5)},
        "experts_in": {"w": w(ks[2], (e.n_experts, d, f), d ** -0.5)},
        "experts_out": {"w": w(ks[3], (e.n_experts, f, d),
                               (f * 2 * cfg.n_layers) ** -0.5)},
    }
    if e.n_shared:
        ks2 = jax.random.split(ks[4], 3)
        fs = e.d_expert * e.n_shared
        p["shared_gate"] = {"w": w(ks2[0], (d, fs), d ** -0.5)}
        p["shared_in"] = {"w": w(ks2[1], (d, fs), d ** -0.5)}
        p["shared_out"] = {"w": w(ks2[2], (fs, d),
                                  (fs * 2 * cfg.n_layers) ** -0.5)}
    return p


def export_moe(p: dict, cfg: ModelConfig) -> dict:
    """Master experts -> serving format (per-expert scale, packed base-3).

    vmap-safe: operates on array leaves only (no python branching on values).
    """
    out = dict(p)
    for name in ("experts_gate", "experts_in", "experts_out"):
        w = p[name]["w"]
        gamma = jnp.mean(jnp.abs(w), axis=(1, 2), keepdims=True) + 1e-6
        trits = jnp.clip(jnp.round(w / gamma), -1, 1).astype(jnp.int8)
        if cfg.ternary.serve_format == "packed":
            packed = jax.vmap(lambda t: twd.pack_ternary(t, row_align=16))(trits)
            out[name] = {"packed": packed,
                         "scale": gamma.astype(jnp.float32)}
        else:
            out[name] = {"trits": trits, "scale": gamma.astype(jnp.float32)}
    from repro.models.ternary_linear import export_tlin
    for name in ("shared_gate", "shared_in", "shared_out"):
        if name in p:
            out[name] = export_tlin(p[name], cfg.ternary)
    return out


def _expert_weights(p: dict, cfg: ModelConfig, x_dtype):
    """-> (wg, wi, wo) dequantized/fake-quantized expert stacks."""
    e = cfg.moe
    kdims = {"experts_gate": cfg.d_model, "experts_in": cfg.d_model,
             "experts_out": e.d_expert}
    out = []
    for name in ("experts_gate", "experts_in", "experts_out"):
        sub = p[name]
        if "w" in sub:
            w = (tq.ternary_fake_quant_stacked(sub["w"])
                 if cfg.ternary.enabled else sub["w"])  # per-expert scale:
            out.append(w.astype(x_dtype))               # EP-shard invariant
        elif "trits" in sub:
            out.append(sub["trits"].astype(x_dtype) * sub["scale"].astype(x_dtype))
        else:
            k = kdims[name]
            w = jax.vmap(lambda pk: twd.unpack_ternary_arith(pk, k))(sub["packed"])
            out.append(w.astype(x_dtype) * sub["scale"].astype(x_dtype))
    return out


def _dispatch_compute(x_tok, weights, router, cfg: ModelConfig,
                      e_start, e_local: int, capacity: int):
    """Route (T, D) tokens, run experts [e_start, e_start+e_local), return
    the partial combine (T, D) (zeros for tokens routed elsewhere)."""
    e: MoeConfig = cfg.moe
    t, d = x_tok.shape
    wg, wi, wo = weights                                         # local stacks
    logits = x_tok.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gate, expert = jax.lax.top_k(probs, e.top_k)                 # (T, K)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    flat_e = expert.reshape(-1)                                  # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e.n_experts)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(flat_e.shape[0]) - starts[sorted_e]
    pos = jnp.zeros_like(flat_e).at[order].set(pos_sorted)       # rank in expert

    local_e = flat_e - e_start
    ok = (local_e >= 0) & (local_e < e_local) & (pos < capacity)
    slot = jnp.where(ok, local_e * capacity + pos, e_local * capacity)

    tok_idx = jnp.repeat(jnp.arange(t), e.top_k)
    x_in = x_tok
    if cfg.ternary.enabled and cfg.ternary.das is not None:
        from repro.core import das as das_lib
        m = das_lib.das_mask(x_in, block_size=cfg.ternary.das.block,
                             keep=cfg.ternary.das.keep)
        x_in = das_lib.das_apply(x_in, m)
    if cfg.ternary.enabled:
        x_in = tq.int8_fake_quant(x_in)
    buf = jnp.zeros((e_local * capacity + 1, d), x_tok.dtype)
    buf = buf.at[slot].set(x_in[tok_idx], mode="drop")
    buf = buf[:-1].reshape(e_local, capacity, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum(
        "ecd,edf->ecf", buf, wi)
    y = jnp.einsum("ecf,efd->ecd", h, wo)                        # (E_l, C, D)

    y_flat = jnp.concatenate([y.reshape(e_local * capacity, d),
                              jnp.zeros((1, d), y.dtype)], axis=0)
    g = jnp.where(ok, gate.reshape(-1), 0.0).astype(y.dtype)
    contrib = y_flat[jnp.minimum(slot, e_local * capacity)] * g[:, None]
    return jnp.zeros((t, d), y.dtype).at[tok_idx].add(contrib)


def _shared_ffn(p: dict, cfg: ModelConfig, x: jax.Array, kernel_mode: str):
    # shared gate/up see the same tokens: compact once on the fused DAS path
    ca = tlin_compact(x, cfg.ternary, p["shared_gate"],
                      kernel_mode=kernel_mode)
    g = tlin_apply(p["shared_gate"], x, cfg.ternary, kernel_mode=kernel_mode,
                   ca=ca)
    u = tlin_apply(p["shared_in"], x, cfg.ternary, kernel_mode=kernel_mode,
                   ca=ca)
    return tlin_apply(p["shared_out"], jax.nn.silu(g) * u, cfg.ternary,
                      kernel_mode=kernel_mode)


def _ep_spec(sub: dict, ep_axis: str):
    """EP PartitionSpec tree for one expert param dict (axis 0 = experts)."""
    return {k: P(ep_axis) for k in sub}


def decode_capacity(cfg: ModelConfig, batch: int) -> int:
    """No-drop per-expert capacity for a decode tick of ``batch`` tokens.

    The training-time capacity ``t * top_k / E * cf`` models a balanced
    router over thousands of tokens; at decode t is the live batch (a
    handful of rows), so a momentarily hot expert overflows the bound and
    the overflow tokens are SILENTLY dropped from its mixture — making a
    request's tokens depend on its batch-mates (batch-variant serving).
    A single expert can receive at most one routed copy of each token, so
    capacity == batch makes drops impossible at decode.
    """
    del cfg
    return max(1, batch)


def moe_apply(p: dict, cfg: ModelConfig, x: jax.Array, *, mesh=None,
              dp_axes=("data",), ep_axis: str = "model",
              kernel_mode: str = "ref", capacity: int | None = None
              ) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).  EP via shard_map when a mesh is given.

    ``capacity`` overrides the per-expert token capacity (serving decode
    passes :func:`decode_capacity` so hot experts never drop tokens; None
    keeps the capacity-factor formula used in training)."""
    e: MoeConfig = cfg.moe
    b, s, d = x.shape

    if mesh is None:
        t = b * s
        cap = capacity if capacity is not None else max(
            1, min(t, int(t * e.top_k / e.n_experts * e.capacity_factor) + 1))
        weights = _expert_weights(p, cfg, x.dtype)
        y = _dispatch_compute(x.reshape(t, d), weights, p["router"], cfg,
                              0, e.n_experts, cap).reshape(b, s, d)
    else:
        ep = mesh.shape[ep_axis]
        dp = 1
        for a in dp_axes:
            dp *= mesh.shape[a]
        if e.n_experts % ep:
            raise ValueError(f"{e.n_experts} experts not divisible by EP={ep}")
        e_local = e.n_experts // ep
        t_local = max(1, (b // dp)) * s
        cap = capacity if capacity is not None else max(
            1, min(t_local, int(t_local * e.top_k / e.n_experts
                                * e.capacity_factor) + 1))

        expert_names = ("experts_gate", "experts_in", "experts_out")
        p_experts = {k: p[k] for k in expert_names}
        specs = {k: _ep_spec(p[k], ep_axis) for k in expert_names}

        def local_fn(x_blk, pe, router):
            ei = jax.lax.axis_index(ep_axis)
            tl = x_blk.shape[0] * x_blk.shape[1]
            weights = _expert_weights(pe, cfg, x_blk.dtype)
            y = _dispatch_compute(x_blk.reshape(tl, d), weights, router, cfg,
                                  ei * e_local, e_local, cap)
            return jax.lax.psum(y, ep_axis).reshape(x_blk.shape)

        y = shard_map(
            local_fn, mesh=mesh,
            in_specs=(P(dp_axes, None, None), specs, P()),
            out_specs=P(dp_axes, None, None),
            check_vma=False,
        )(x, p_experts, p["router"])

    if e.n_shared:
        y = y + _shared_ffn(p, cfg, x, kernel_mode)
    return y
