"""Model zoo: composable decoder stacks for all assigned architectures."""
from . import model  # noqa: F401
