"""RWKV6 "Finch" block: time-mix with data-dependent per-channel decay +
channel-mix FFN.  All projections are TENET ternary linears (the paper's
GLA experiment, Sec. V-D, is the template for attention-free models).

Simplifications vs. the full Finch recipe (noted in DESIGN.md): token-shift
uses learned static mix coefficients (the data-dependent LoRA shift is
dropped); the decay LoRA  w_t = exp(-exp(w0 + tanh(x W_d1) W_d2))  — the
headline data-dependent decay — is kept.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.linear_attn import chunked_linear_attn, linear_attn_step
from repro.models.ternary_linear import tlin_apply, tlin_init

__all__ = ["rwkv_init", "rwkv_time_mix", "rwkv_channel_mix",
           "rwkv_time_mix_step", "rwkv_channel_mix_step"]

DECAY_LORA = 64


def rwkv_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    h, hd = cfg.n_heads, cfg.head_dim_
    ks = jax.random.split(key, 12)
    return {
        # time-mix
        "wr": tlin_init(ks[0], d, h * hd, dtype),
        "wk": tlin_init(ks[1], d, h * hd, dtype),
        "wv": tlin_init(ks[2], d, h * hd, dtype),
        "wg": tlin_init(ks[3], d, h * hd, dtype),
        "wo": tlin_init(ks[4], h * hd, d, dtype,
                        scale=(h * hd * 2 * cfg.n_layers) ** -0.5),
        "w_decay1": L.dense_init(ks[5], d, DECAY_LORA, dtype),
        "w_decay2": L.dense_init(ks[6], DECAY_LORA, h * hd, dtype, scale=0.1),
        "w0": jnp.full((h * hd,), -2.0, dtype),   # base decay ~ exp(-exp(-2))
        "u": (jax.random.normal(ks[7], (h, hd), jnp.float32) * 0.1).astype(dtype),
        "mix_t": jnp.full((4, d), 0.5, dtype),    # r/k/v/g token-shift mixes
        "ln_x": {"scale": jnp.ones((h * hd,), dtype),
                 "bias": jnp.zeros((h * hd,), dtype)},
        # channel-mix
        "ck": tlin_init(ks[8], d, f, dtype),
        "cv": tlin_init(ks[9], f, d, dtype, scale=(f * 2 * cfg.n_layers) ** -0.5),
        "cr": tlin_init(ks[10], d, d, dtype),
        "mix_c": jnp.full((2, d), 0.5, dtype),    # k/r mixes
    }


def _shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """x_{t-1} stream: zero (or carried `prev`) at t=0."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev.astype(x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _decay_log(p, xr):
    """log w_t = -exp(w0 + tanh(x Wd1) Wd2)  (per channel, <= 0)."""
    lora = jnp.tanh(xr.astype(jnp.float32) @ p["w_decay1"].astype(jnp.float32))
    lw = p["w0"].astype(jnp.float32) + lora @ p["w_decay2"].astype(jnp.float32)
    return -jnp.exp(jnp.clip(lw, -8.0, 4.0))


def _groupnorm(p, x, h, hd, eps=1e-5):
    b, l, _ = x.shape
    xh = x.reshape(b, l, h, hd).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    y = ((xh - mu) * jax.lax.rsqrt(var + eps)).reshape(b, l, h * hd)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def _time_mix_proj(p, cfg, x, x_prev, kernel_mode):
    h, hd = cfg.n_heads, cfg.head_dim_
    mix = p["mix_t"].astype(x.dtype)
    xr = x * mix[0] + x_prev * (1 - mix[0])
    xk = x * mix[1] + x_prev * (1 - mix[1])
    xv = x * mix[2] + x_prev * (1 - mix[2])
    xg = x * mix[3] + x_prev * (1 - mix[3])
    tc = cfg.ternary
    b, l, _ = x.shape
    r = tlin_apply(p["wr"], xr, tc, kernel_mode=kernel_mode).reshape(b, l, h, hd)
    k = tlin_apply(p["wk"], xk, tc, kernel_mode=kernel_mode).reshape(b, l, h, hd)
    v = tlin_apply(p["wv"], xv, tc, kernel_mode=kernel_mode).reshape(b, l, h, hd)
    g = tlin_apply(p["wg"], xg, tc, kernel_mode=kernel_mode)
    la = _decay_log(p, xr).reshape(b, l, h, hd)
    return r, k, v, g, la


def _channel_mix(p, cfg, x, x_prev, kernel_mode):
    mix = p["mix_c"].astype(x.dtype)
    xk = x * mix[0] + x_prev * (1 - mix[0])
    xr = x * mix[1] + x_prev * (1 - mix[1])
    tc = cfg.ternary
    k = tlin_apply(p["ck"], xk, tc, kernel_mode=kernel_mode)
    kv = tlin_apply(p["cv"], jnp.square(jax.nn.relu(k)), tc,
                    kernel_mode=kernel_mode)
    r = tlin_apply(p["cr"], xr, tc, kernel_mode=kernel_mode)
    return jax.nn.sigmoid(r) * kv


def rwkv_time_mix(p: dict, cfg: ModelConfig, x: jax.Array, *,
                  kernel_mode: str = "ref", chunk: int = 64,
                  wkv0: jax.Array | None = None,
                  prev: jax.Array | None = None):
    """Time-mix over a sequence.  x: (B, L, D) (pre-normed).

    Returns (y, {"wkv", "shift_t"}).
    """
    h, hd = cfg.n_heads, cfg.head_dim_
    b = x.shape[0]
    r, k, v, g, la = _time_mix_proj(p, cfg, x, _shift(x, prev), kernel_mode)
    o, s_fin = chunked_linear_attn(r, k, v, la, chunk=chunk, mode="rwkv",
                                   u=p["u"], s0=wkv0)
    o = _groupnorm(p["ln_x"], o.reshape(b, -1, h * hd), h, hd)
    o = o * jax.nn.silu(g)
    y = tlin_apply(p["wo"], o, cfg.ternary, kernel_mode=kernel_mode)
    return y, {"wkv": s_fin, "shift_t": x[:, -1:]}


def rwkv_channel_mix(p: dict, cfg: ModelConfig, x: jax.Array, *,
                     kernel_mode: str = "ref",
                     prev: jax.Array | None = None):
    """Channel-mix FFN.  Returns (y, shift_c = x[:, -1:])."""
    y = _channel_mix(p, cfg, x, _shift(x, prev), kernel_mode)
    return y, x[:, -1:]


def rwkv_time_mix_step(p: dict, cfg: ModelConfig, x: jax.Array, state: dict, *,
                       kernel_mode: str = "ref"):
    """One-token time-mix.  x: (B, 1, D); state {"wkv", "shift_t"}."""
    h, hd = cfg.n_heads, cfg.head_dim_
    b = x.shape[0]
    r, k, v, g, la = _time_mix_proj(p, cfg, x, state["shift_t"].astype(x.dtype),
                                    kernel_mode)
    o, s_new = linear_attn_step(r[:, 0], k[:, 0], v[:, 0], la[:, 0],
                                state["wkv"], mode="rwkv", u=p["u"])
    o = _groupnorm(p["ln_x"], o.reshape(b, 1, h * hd), h, hd)
    o = o * jax.nn.silu(g)
    y = tlin_apply(p["wo"], o, cfg.ternary, kernel_mode=kernel_mode)
    return y, {"wkv": s_new, "shift_t": x}


def rwkv_channel_mix_step(p: dict, cfg: ModelConfig, x: jax.Array,
                          prev: jax.Array, *, kernel_mode: str = "ref"):
    y = _channel_mix(p, cfg, x, prev.astype(x.dtype), kernel_mode)
    return y, x
