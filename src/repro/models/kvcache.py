"""KV caches and recurrent states for serving, behind a tagged CacheSpec API.

Three attention cache layouts (``CacheSpec.layout``):
  * full  — (B, S_max, Hkv, Dh) with a write cursor: the conventional cache
    (the paper's "naive" baseline whose DRAM traffic LPSA removes).
  * ring  — (B, sink+window, Hkv, Dh) + slot->position map: O(TL_SA) memory
    at ANY context length (the LPSA decode cache; core.lpsa.decode_slot).
  * paged — one (num_pages, page_size, Hkv, Dh) K/V arena shared by every
    sequence, addressed through per-sequence int32 page tables
    (B, pages_per_seq).  Memory scales with *live tokens*, not
    B x S_max, and pages holding a common prompt prefix can be shared
    between sequences by refcount (repro.serve.kvpool).  Page 0 is a
    reserved null page: unmapped page-table entries point at it and its
    positions stay -1, so gathers through unmapped entries are masked.

Recurrent states for SSM/linear-attention families (mamba / rwkv / gla) are
fixed-size per token — the "native sub-quadratic" path of the zoo — and get
their own CacheSpec layouts so one factory covers the whole zoo.

The legacy per-layout constructors (``init_attn_full`` / ``init_attn_ring`` /
``init_mamba_state`` / ``init_rwkv_state`` / ``init_gla_state``) remain as
thin deprecated shims over :func:`init_cache`.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SsmConfig
from repro.core.lpsa import decode_slot

__all__ = [
    "CacheSpec", "CACHE_LAYOUTS", "init_cache", "is_paged",
    "attn_write", "attn_read", "ring_from_stream",
    # deprecated shims
    "init_attn_full", "init_attn_ring", "init_mamba_state",
    "init_rwkv_state", "init_gla_state",
]

CACHE_LAYOUTS = ("full", "ring", "paged", "mamba", "rwkv", "gla")


@dataclass(frozen=True)
class CacheSpec:
    """Tagged description of one layer's serving cache.

    ``layout`` selects the variant; only the fields that variant reads are
    meaningful (full: max_len; ring: sink+window; paged: page_size +
    num_pages; recurrent layouts: batch only).  ``batch`` is the number of
    sequences for the per-sequence layouts — the paged arena itself is
    batch-free (sequences address it through page tables).
    """
    layout: str
    batch: int
    max_len: int = 0
    sink: int = 0
    window: int = 0
    page_size: int = 0
    num_pages: int = 0
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        if self.layout not in CACHE_LAYOUTS:
            raise ValueError(
                f"unknown cache layout {self.layout!r}: valid layouts are "
                f"{', '.join(CACHE_LAYOUTS)}")
        if self.layout == "paged" and (self.page_size < 1 or self.num_pages < 2):
            raise ValueError(
                "paged cache needs page_size >= 1 and num_pages >= 2 "
                f"(page 0 is the reserved null page); got page_size="
                f"{self.page_size}, num_pages={self.num_pages}")


def init_cache(cfg: ModelConfig, spec: CacheSpec) -> dict:
    """One layer's cache pytree for ``spec`` — the single factory replacing
    the per-layout ``init_attn_*`` / ``init_*_state`` constructors."""
    if spec.layout == "full":
        shp = (spec.batch, spec.max_len, cfg.n_kv_heads, cfg.head_dim_)
        return {"k": jnp.zeros(shp, spec.dtype),
                "v": jnp.zeros(shp, spec.dtype),
                "pos": jnp.full((spec.batch, spec.max_len), -1, jnp.int32)}
    if spec.layout == "ring":
        s = spec.sink + spec.window
        shp = (spec.batch, s, cfg.n_kv_heads, cfg.head_dim_)
        return {"k": jnp.zeros(shp, spec.dtype),
                "v": jnp.zeros(shp, spec.dtype),
                "pos": jnp.full((spec.batch, s), -1, jnp.int32)}
    if spec.layout == "paged":
        shp = (spec.num_pages, spec.page_size, cfg.n_kv_heads, cfg.head_dim_)
        return {"k_pages": jnp.zeros(shp, spec.dtype),
                "v_pages": jnp.zeros(shp, spec.dtype),
                "pos_pages": jnp.full((spec.num_pages, spec.page_size), -1,
                                      jnp.int32)}
    if spec.layout == "mamba":
        from repro.models.mamba2 import init_ssd_buffers
        s: SsmConfig = cfg.ssm or SsmConfig()
        d_inner = s.expand * cfg.d_model
        n_heads = d_inner // s.head_dim
        return {
            "conv": jnp.zeros((spec.batch, s.conv_width - 1, d_inner),
                              jnp.float32),
            "ssm": jnp.zeros((spec.batch, n_heads, s.head_dim, s.state_dim),
                             jnp.float32),
            # partial-chunk token buffers: decode replays the prefill chunk
            # grid row-by-row (mamba2.mamba_decode), so the state carries the
            # last full-chunk boundary plus the buffered remainder tokens.
            **init_ssd_buffers(cfg, spec.batch),
        }
    if spec.layout == "rwkv":
        hd = cfg.head_dim_
        return {
            "wkv": jnp.zeros((spec.batch, cfg.n_heads, hd, hd), jnp.float32),
            "shift_t": jnp.zeros((spec.batch, 1, cfg.d_model), jnp.float32),
            "shift_c": jnp.zeros((spec.batch, 1, cfg.d_model), jnp.float32),
        }
    if spec.layout == "gla":
        hd = cfg.head_dim_
        return {"s": jnp.zeros((spec.batch, cfg.n_heads, hd, hd), jnp.float32)}
    raise ValueError(spec.layout)  # unreachable (CacheSpec validates)


def is_paged(cache: dict) -> bool:
    return isinstance(cache, dict) and "k_pages" in cache


# --------------------------------------------------------------------------
# attention cache write / read
# --------------------------------------------------------------------------

def attn_write(cache: dict, k_new: jax.Array, v_new: jax.Array, t: jax.Array,
               *, sink: int, window: int, ring: bool,
               page_table: jax.Array | None = None) -> dict:
    """Insert one token's K/V per sequence at absolute positions t.

    t: (B,) int32 — each sequence's own absolute position (a scalar t
    broadcasts, preserving the old lock-step behaviour).  Slots are computed
    per sequence (core.lpsa.decode_slot is elementwise over t), so sequences
    at different decode depths coexist in one batched cache.  A full-cache
    write past max_len is dropped (its slot keeps pos = -1 and stays
    masked) rather than clobbering the last slot.

    Paged caches additionally take ``page_table`` (B, pages_per_seq) int32:
    the write lands in page ``page_table[b, t // page_size]`` at offset
    ``t % page_size``.  Rows with t < 0 (inactive slots) are routed to the
    reserved null page 0 with pos = -1, so they never corrupt shared pages.
    """
    if is_paged(cache):
        return _paged_write(cache, k_new, v_new, t, page_table)
    b = cache["k"].shape[0]
    t = jnp.asarray(t, jnp.int32)
    if t.ndim == 0:
        t = jnp.broadcast_to(t, (b,))
    slot = decode_slot(t, sink, window) if ring else t          # (B,)
    bidx = jnp.arange(b)
    k = cache["k"].at[bidx, slot].set(k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[bidx, slot].set(v_new[:, 0].astype(cache["v"].dtype))
    pos = cache["pos"].at[bidx, slot].set(t)
    return {"k": k, "v": v, "pos": pos}


def _paged_write(cache: dict, k_new: jax.Array, v_new: jax.Array,
                 t: jax.Array, page_table: jax.Array) -> dict:
    if page_table is None:
        raise ValueError("paged cache write requires a page_table")
    b = k_new.shape[0]
    ps = cache["k_pages"].shape[1]
    t = jnp.asarray(t, jnp.int32)
    if t.ndim == 0:
        t = jnp.broadcast_to(t, (b,))
    valid = t >= 0
    pi = jnp.where(valid, t // ps, 0)
    off = jnp.where(valid, t % ps, 0)
    phys = jnp.where(valid, page_table[jnp.arange(b), pi], 0)   # (B,)
    k = cache["k_pages"].at[phys, off].set(
        k_new[:, 0].astype(cache["k_pages"].dtype))
    v = cache["v_pages"].at[phys, off].set(
        v_new[:, 0].astype(cache["v_pages"].dtype))
    pos = cache["pos_pages"].at[phys, off].set(jnp.where(valid, t, -1))
    return {"k_pages": k, "v_pages": v, "pos_pages": pos}


def attn_read(cache: dict, page_table: jax.Array | None = None):
    """-> (k (B,S,Hkv,Dh), v, k_pos (B,S)); invalid slots have pos = -1.

    For paged caches the per-sequence view is gathered through
    ``page_table``: S = pages_per_seq * page_size, and gathered index
    ``i == absolute position i`` (page tables map logical page j to
    positions [j*page_size, (j+1)*page_size)), so the view is laid out
    exactly like a full cache — downstream attention (flash_masked, the
    LPSA decode kernels) is layout-oblivious.
    """
    if is_paged(cache):
        if page_table is None:
            raise ValueError("paged cache read requires a page_table")
        kp, vp, pp = cache["k_pages"], cache["v_pages"], cache["pos_pages"]
        b, n = page_table.shape
        ps = kp.shape[1]
        k = kp[page_table].reshape(b, n * ps, *kp.shape[2:])
        v = vp[page_table].reshape(b, n * ps, *vp.shape[2:])
        pos = pp[page_table].reshape(b, n * ps)
        return k, v, pos
    return cache["k"], cache["v"], cache["pos"]


def ring_from_stream(cfg: ModelConfig, state, *, sink: int, window: int) -> dict:
    """Convert a core.lpsa.lpsa_prefill scan carry into a decode ring cache.

    state = (k_sink, v_sink, k_win, v_win, t_end): sink slots land in ring
    slots [0, sink); window tokens (positions t_end-window..t_end-1, oldest
    first in the stream buffer) land at their decode_slot positions.
    """
    k_sink, v_sink, k_win, v_win, t_end = state
    dtype = k_sink.dtype
    b = k_sink.shape[0]
    # sink slots [0, sink): valid while position < t_end
    sink_pos = jnp.arange(sink)
    sink_valid = sink_pos < t_end
    # each ring slot j in [sink, sink+window) pulls the unique stream-buffer
    # position p with p ≡ (j - sink) (mod window) inside [t_end-window, t_end)
    j = jnp.arange(window)                       # slot offset = j
    base = t_end - window                        # stream buffer start position
    p = base + (j - (base - sink)) % window
    ring_valid = (p >= sink) & (p >= 0)
    idx = jnp.clip(p - base, 0, window - 1)      # index into the stream buffer
    k_ring = jnp.take(k_win, idx, axis=1).astype(dtype)
    v_ring = jnp.take(v_win, idx, axis=1).astype(dtype)
    k = jnp.concatenate([k_sink.astype(dtype), k_ring], axis=1)
    v = jnp.concatenate([v_sink.astype(dtype), v_ring], axis=1)
    pos = jnp.concatenate([jnp.where(sink_valid, sink_pos, -1),
                           jnp.where(ring_valid, p, -1)]).astype(jnp.int32)
    # per-sequence position map: prefill runs the whole batch in lock-step,
    # so every sequence starts from the same slot->position assignment
    pos = jnp.broadcast_to(pos[None], (b, pos.shape[0]))
    return {"k": k, "v": v, "pos": pos}


# --------------------------------------------------------------------------
# deprecated per-layout constructors (shims over init_cache)
# --------------------------------------------------------------------------

_DEPRECATION_WARNED: set = set()


def _warn_deprecated(old: str, new: str) -> None:
    if old not in _DEPRECATION_WARNED:   # once per process, not per trace
        _DEPRECATION_WARNED.add(old)
        warnings.warn(
            f"{old} is deprecated; use init_cache(cfg, CacheSpec({new})) "
            f"(models/kvcache.py)", DeprecationWarning, stacklevel=3)


def init_attn_full(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> dict:
    _warn_deprecated("init_attn_full", "layout='full', ...")
    return init_cache(cfg, CacheSpec("full", batch, max_len=max_len,
                                     dtype=dtype))


def init_attn_ring(cfg: ModelConfig, batch: int, sink: int, window: int,
                   dtype=jnp.bfloat16) -> dict:
    _warn_deprecated("init_attn_ring", "layout='ring', ...")
    return init_cache(cfg, CacheSpec("ring", batch, sink=sink, window=window,
                                     dtype=dtype))


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    _warn_deprecated("init_mamba_state", "layout='mamba', ...")
    return init_cache(cfg, CacheSpec("mamba", batch))


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    _warn_deprecated("init_rwkv_state", "layout='rwkv', ...")
    return init_cache(cfg, CacheSpec("rwkv", batch))


def init_gla_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    _warn_deprecated("init_gla_state", "layout='gla', ...")
    return init_cache(cfg, CacheSpec("gla", batch))
