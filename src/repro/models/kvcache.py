"""KV caches and recurrent states for serving.

Two attention cache layouts:
  * full  — (B, S_max, Hkv, Dh) with a write cursor: the conventional cache
    (the paper's "naive" baseline whose DRAM traffic LPSA removes).
  * ring  — (B, sink+window, Hkv, Dh) + slot->position map: O(TL_SA) memory
    at ANY context length (the LPSA decode cache; core.lpsa.decode_slot).

Recurrent states for SSM/linear-attention families (mamba / rwkv / gla) are
fixed-size per token — the "native sub-quadratic" path of the zoo.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SsmConfig
from repro.core.lpsa import decode_slot

__all__ = [
    "init_attn_full", "init_attn_ring", "attn_write", "attn_read",
    "ring_from_stream", "init_mamba_state", "init_rwkv_state",
    "init_gla_state",
]


# --------------------------------------------------------------------------
# attention caches
# --------------------------------------------------------------------------

def init_attn_full(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> dict:
    shp = (batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype),
            "pos": jnp.full((batch, max_len), -1, jnp.int32)}


def init_attn_ring(cfg: ModelConfig, batch: int, sink: int, window: int,
                   dtype=jnp.bfloat16) -> dict:
    shp = (batch, sink + window, cfg.n_kv_heads, cfg.head_dim_)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype),
            "pos": jnp.full((batch, sink + window), -1, jnp.int32)}


def attn_write(cache: dict, k_new: jax.Array, v_new: jax.Array, t: jax.Array,
               *, sink: int, window: int, ring: bool) -> dict:
    """Insert one token's K/V per sequence at absolute positions t.

    t: (B,) int32 — each sequence's own absolute position (a scalar t
    broadcasts, preserving the old lock-step behaviour).  Slots are computed
    per sequence (core.lpsa.decode_slot is elementwise over t), so sequences
    at different decode depths coexist in one batched cache.  A full-cache
    write past max_len is dropped (its slot keeps pos = -1 and stays
    masked) rather than clobbering the last slot.
    """
    b = cache["k"].shape[0]
    t = jnp.asarray(t, jnp.int32)
    if t.ndim == 0:
        t = jnp.broadcast_to(t, (b,))
    slot = decode_slot(t, sink, window) if ring else t          # (B,)
    bidx = jnp.arange(b)
    k = cache["k"].at[bidx, slot].set(k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[bidx, slot].set(v_new[:, 0].astype(cache["v"].dtype))
    pos = cache["pos"].at[bidx, slot].set(t)
    return {"k": k, "v": v, "pos": pos}


def attn_read(cache: dict):
    """-> (k (B,S,Hkv,Dh), v, k_pos (B,S)); invalid slots have pos = -1."""
    return cache["k"], cache["v"], cache["pos"]


def ring_from_stream(cfg: ModelConfig, state, *, sink: int, window: int) -> dict:
    """Convert a core.lpsa.lpsa_prefill scan carry into a decode ring cache.

    state = (k_sink, v_sink, k_win, v_win, t_end): sink slots land in ring
    slots [0, sink); window tokens (positions t_end-window..t_end-1, oldest
    first in the stream buffer) land at their decode_slot positions.
    """
    k_sink, v_sink, k_win, v_win, t_end = state
    dtype = k_sink.dtype
    b = k_sink.shape[0]
    # sink slots [0, sink): valid while position < t_end
    sink_pos = jnp.arange(sink)
    sink_valid = sink_pos < t_end
    # each ring slot j in [sink, sink+window) pulls the unique stream-buffer
    # position p with p ≡ (j - sink) (mod window) inside [t_end-window, t_end)
    j = jnp.arange(window)                       # slot offset = j
    base = t_end - window                        # stream buffer start position
    p = base + (j - (base - sink)) % window
    ring_valid = (p >= sink) & (p >= 0)
    idx = jnp.clip(p - base, 0, window - 1)      # index into the stream buffer
    k_ring = jnp.take(k_win, idx, axis=1).astype(dtype)
    v_ring = jnp.take(v_win, idx, axis=1).astype(dtype)
    k = jnp.concatenate([k_sink.astype(dtype), k_ring], axis=1)
    v = jnp.concatenate([v_sink.astype(dtype), v_ring], axis=1)
    pos = jnp.concatenate([jnp.where(sink_valid, sink_pos, -1),
                           jnp.where(ring_valid, p, -1)]).astype(jnp.int32)
    # per-sequence position map: prefill runs the whole batch in lock-step,
    # so every sequence starts from the same slot->position assignment
    pos = jnp.broadcast_to(pos[None], (b, pos.shape[0]))
    return {"k": k, "v": v, "pos": pos}


# --------------------------------------------------------------------------
# recurrent states
# --------------------------------------------------------------------------

def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    s: SsmConfig = cfg.ssm or SsmConfig()
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, d_inner), dtype),
        "ssm": jnp.zeros((batch, n_heads, s.head_dim, s.state_dim), dtype),
    }


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    hd = cfg.head_dim_
    return {
        "wkv": jnp.zeros((batch, cfg.n_heads, hd, hd), dtype),
        "shift_t": jnp.zeros((batch, 1, cfg.d_model), dtype),   # time-mix x_{t-1}
        "shift_c": jnp.zeros((batch, 1, cfg.d_model), dtype),   # channel-mix
    }


def init_gla_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    hd = cfg.head_dim_
    return {"s": jnp.zeros((batch, cfg.n_heads, hd, hd), dtype)}
