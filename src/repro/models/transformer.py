"""Composable decoder stack: per-layer mixers, scan-over-groups, remat.

The repeating `layer_pattern` of a config becomes one scan *group*: the
group body unrolls the pattern's blocks; `lax.scan` iterates groups with
per-position parameter stacks (keeps the lowered HLO O(pattern), not
O(layers) — essential for compiling 61-layer trillion-param configs against
512 partitions).  Layers left over when the pattern doesn't divide n_layers
(gemma3's 26 = 4*6 + 2) run as an unrolled tail.  Zamba2's weight-shared
attention block is threaded through as non-scanned `shared` params.

Three phases share the same parameters:
  train    — full-sequence differentiable pass (fake-quant ternary, DAS)
  prefill  — serving: streaming LPSA/local (ring caches) or full attention
  decode   — one token against the caches/states
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import gla as G
from repro.models import kvcache as KV
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MOE
from repro.models import rwkv6 as R
from repro.models.ternary_linear import tlin_apply, tlin_compact, tlin_init

__all__ = ["Runtime", "stack_init", "stack_train", "stack_prefill",
           "stack_decode", "layer_cache_spec", "init_layer_cache",
           "ffn_init", "ffn_apply"]


@dataclass(frozen=True)
class Runtime:
    """Execution context threaded through the model functions."""
    mesh: Any = None
    dp_axes: tuple = ("data",)
    ep_axis: str = "model"
    kernel_mode: str = "ref"
    serve_sparse: bool = True      # LPSA on global-attention layers at serve


# --------------------------------------------------------------------------
# FFN
# --------------------------------------------------------------------------

def ffn_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    out_scale = (f * 2 * cfg.n_layers) ** -0.5
    if cfg.ffn_kind == "mlp":
        return {"w_in": tlin_init(ks[0], d, f, dtype),
                "w_out": tlin_init(ks[1], f, d, dtype, scale=out_scale)}
    return {"w_gate": tlin_init(ks[0], d, f, dtype),
            "w_in": tlin_init(ks[1], d, f, dtype),
            "w_out": tlin_init(ks[2], f, d, dtype, scale=out_scale)}


def ffn_apply(p: dict, cfg: ModelConfig, x: jax.Array, *, kernel_mode="ref"):
    act = L.ACT[cfg.act]
    tc = cfg.ternary
    if "w_gate" in p:
        # gate and up share the input: compact once for the fused DAS path
        ca = tlin_compact(x, tc, p["w_gate"], kernel_mode=kernel_mode)
        h = act(tlin_apply(p["w_gate"], x, tc, kernel_mode=kernel_mode,
                           ca=ca)) * \
            tlin_apply(p["w_in"], x, tc, kernel_mode=kernel_mode, ca=ca)
    else:
        h = act(tlin_apply(p["w_in"], x, tc, kernel_mode=kernel_mode))
    return tlin_apply(p["w_out"], h, tc, kernel_mode=kernel_mode)


def _mixer_ffn(p: dict, cfg: ModelConfig, x: jax.Array, rt: Runtime,
               decode: bool = False):
    """The FFN/MoE half of an attention/gla block.  ``decode`` switches MoE
    to the no-drop capacity (a hot expert must never drop a live request's
    token mid-decode; see moe.decode_capacity)."""
    if cfg.moe is not None:
        cap = (MOE.decode_capacity(cfg, x.shape[0] * x.shape[1])
               if decode else None)
        return MOE.moe_apply(p["moe"], cfg, x, mesh=rt.mesh,
                             dp_axes=rt.dp_axes, ep_axis=rt.ep_axis,
                             kernel_mode=rt.kernel_mode, capacity=cap)
    return ffn_apply(p["ffn"], cfg, x, kernel_mode=rt.kernel_mode)


# --------------------------------------------------------------------------
# per-block init
# --------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, kind: str, dtype=jnp.float32,
               shared_attn: bool = False) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    p: dict = {"norm1": L.init_rmsnorm(d, dtype)}
    if kind in ("attn", "local"):
        if not shared_attn:
            p["attn"] = A.attn_init(ks[0], cfg, dtype)
        p["norm2"] = L.init_rmsnorm(d, dtype)
        if cfg.moe is not None:
            p["moe"] = MOE.moe_init(ks[1], cfg, dtype)
        else:
            p["ffn"] = ffn_init(ks[1], cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = M.mamba_init(ks[0], cfg, dtype)
    elif kind == "rwkv":
        p["rwkv"] = R.rwkv_init(ks[0], cfg, dtype)
        p["norm2"] = L.init_rmsnorm(d, dtype)
    elif kind == "gla":
        p["gla"] = G.gla_init(ks[0], cfg, dtype)
        p["norm2"] = L.init_rmsnorm(d, dtype)
        p["ffn"] = ffn_init(ks[1], cfg, dtype)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    return p


def stack_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    kinds = cfg.layer_kinds()
    pat = cfg.layer_pattern
    plen = len(pat)
    n_groups, tail = (divmod(cfg.n_layers, plen) if cfg.scan_layers
                      else (0, cfg.n_layers))
    keys = jax.random.split(key, cfg.n_layers + 1)
    shared = (A.attn_init(keys[-1], cfg, dtype)
              if cfg.shared_attn and any(k in ("attn", "local") for k in kinds)
              else None)

    def one(i):
        return block_init(keys[i], cfg, kinds[i], dtype,
                          shared_attn=cfg.shared_attn and kinds[i] in ("attn", "local"))

    stacked = None
    if n_groups:
        per_pos = []
        for j in range(plen):
            trees = [one(g * plen + j) for g in range(n_groups)]
            per_pos.append(jax.tree.map(lambda *xs: jnp.stack(xs), *trees))
        stacked = tuple(per_pos)
    tail_params = tuple(one(n_groups * plen + i) for i in range(tail))
    return {"stacked": stacked, "tail": tail_params, "shared": shared}


# --------------------------------------------------------------------------
# phase bodies
# --------------------------------------------------------------------------

def _attn_params(bp: dict, shared):
    return bp["attn"] if "attn" in bp else shared


def block_train(bp: dict, cfg: ModelConfig, x: jax.Array, kind: str,
                shared, rt: Runtime) -> jax.Array:
    km = rt.kernel_mode
    if kind in ("attn", "local"):
        x = x + A.attn_train(_attn_params(bp, shared), cfg,
                             L.rmsnorm(bp["norm1"], x), kind,
                             serve_sparse=rt.serve_sparse, kernel_mode=km)
        x = x + _mixer_ffn(bp, cfg, L.rmsnorm(bp["norm2"], x), rt)
    elif kind == "mamba":
        y, _ = M.mamba_train(bp["mamba"], cfg, L.rmsnorm(bp["norm1"], x),
                             kernel_mode=km)
        x = x + y
    elif kind == "rwkv":
        x, _ = _rwkv_block_seq(bp, cfg, x, km, None)
    elif kind == "gla":
        y, _ = G.gla_train(bp["gla"], cfg, L.rmsnorm(bp["norm1"], x),
                           kernel_mode=km)
        x = x + y
        x = x + _mixer_ffn(bp, cfg, L.rmsnorm(bp["norm2"], x), rt)
    return x


def _rwkv_block_seq(bp, cfg, x, km, state):
    """RWKV block over a sequence: time-mix then channel-mix (pre-norms)."""
    xt = L.rmsnorm(bp["norm1"], x)
    y_t, st_t = R.rwkv_time_mix(bp["rwkv"], cfg, xt, kernel_mode=km,
                                wkv0=state["wkv"] if state else None,
                                prev=state["shift_t"] if state else None)
    x1 = x + y_t
    xc = L.rmsnorm(bp["norm2"], x1)
    y_c, shift_c = R.rwkv_channel_mix(bp["rwkv"], cfg, xc, kernel_mode=km,
                                      prev=state["shift_c"] if state else None)
    return x1 + y_c, {**st_t, "shift_c": shift_c}


def block_prefill(bp: dict, cfg: ModelConfig, x: jax.Array, kind: str,
                  shared, rt: Runtime, batch: int, max_len: int):
    """-> (x, cache_entry) with caches ready for decode at position L."""
    km = rt.kernel_mode
    dt = x.dtype
    if kind in ("attn", "local"):
        ap = _attn_params(bp, shared)
        xin = L.rmsnorm(bp["norm1"], x)
        sink, window = A.kind_sink_window(cfg, kind, rt.serve_sparse)
        if sink < A.FULL_SINK:   # sparse: streaming prefill -> ring cache
            y, state = A.attn_prefill_streaming(ap, cfg, xin, kind,
                                                kernel_mode=km)
            cache = KV.ring_from_stream(cfg, state, sink=sink, window=window)
        else:                    # full attention -> full cache
            q, k, v = A.qkv_project(ap, cfg, xin, kernel_mode=km)
            pos = jnp.arange(x.shape[1])
            rp = A._rope_fn(cfg)
            q, k = rp(q, pos), rp(k, pos)
            o = A.flash_masked(q, k, v, pos, pos, sink=A.FULL_SINK, window=0,
                               softcap=cfg.attn_softcap)
            y = tlin_apply(ap["wo"], o.reshape(x.shape[0], x.shape[1], -1),
                           cfg.ternary, kernel_mode=km)
            full = KV.init_cache(cfg, KV.CacheSpec("full", batch,
                                                   max_len=max_len, dtype=dt))
            kpad = full["k"].at[:, :k.shape[1]].set(k.astype(dt))
            vpad = full["v"].at[:, :v.shape[1]].set(v.astype(dt))
            ppad = full["pos"].at[:, :k.shape[1]].set(pos.astype(jnp.int32))
            cache = {"k": kpad, "v": vpad, "pos": ppad}
        x = x + y
        x = x + _mixer_ffn(bp, cfg, L.rmsnorm(bp["norm2"], x), rt)
        return x, cache
    if kind == "mamba":
        y, state = M.mamba_train(
            bp["mamba"], cfg, L.rmsnorm(bp["norm1"], x), kernel_mode=km,
            return_state=True)
        return x + y, state
    if kind == "rwkv":
        return _rwkv_block_seq(bp, cfg, x, km, None)
    if kind == "gla":
        y, s_fin = G.gla_train(bp["gla"], cfg, L.rmsnorm(bp["norm1"], x),
                               kernel_mode=km)
        x = x + y
        x = x + _mixer_ffn(bp, cfg, L.rmsnorm(bp["norm2"], x), rt)
        return x, {"s": s_fin}
    raise ValueError(kind)


def block_decode(bp: dict, cfg: ModelConfig, x: jax.Array, kind: str,
                 cache, t, shared, rt: Runtime,
                 page_table: jax.Array | None = None):
    """One-token decode; t is scalar (lock-step) or (B,) per-sequence
    positions (continuous batching) — recurrent mixers are position-free.
    ``page_table`` addresses paged attention caches (ignored by every other
    layout)."""
    km = rt.kernel_mode
    if kind in ("attn", "local"):
        y, cache = A.attn_decode(_attn_params(bp, shared), cfg,
                                 L.rmsnorm(bp["norm1"], x), cache, t, kind,
                                 serve_sparse=rt.serve_sparse, kernel_mode=km,
                                 page_table=page_table)
        x = x + y
        x = x + _mixer_ffn(bp, cfg, L.rmsnorm(bp["norm2"], x), rt,
                           decode=True)
        return x, cache
    if kind == "mamba":
        y, cache = M.mamba_decode(bp["mamba"], cfg,
                                  L.rmsnorm(bp["norm1"], x), cache, t,
                                  kernel_mode=km)
        return x + y, cache
    if kind == "rwkv":
        xt = L.rmsnorm(bp["norm1"], x)
        y_t, st = R.rwkv_time_mix_step(bp["rwkv"], cfg, xt, cache,
                                       kernel_mode=km)
        x1 = x + y_t
        xc = L.rmsnorm(bp["norm2"], x1)
        y_c, shift_c = R.rwkv_channel_mix_step(bp["rwkv"], cfg, xc,
                                               cache["shift_c"],
                                               kernel_mode=km)
        return x1 + y_c, {**st, "shift_c": shift_c}
    if kind == "gla":
        y, cache = G.gla_decode(bp["gla"], cfg, L.rmsnorm(bp["norm1"], x),
                                cache, kernel_mode=km)
        x = x + y
        x = x + _mixer_ffn(bp, cfg, L.rmsnorm(bp["norm2"], x), rt,
                           decode=True)
        return x, cache
    raise ValueError(kind)


# --------------------------------------------------------------------------
# cache init (decode entry point without a prefill pass)
# --------------------------------------------------------------------------

def layer_cache_spec(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     rt: Runtime, dtype=jnp.bfloat16, *, page_size: int = 0,
                     num_pages: int = 0) -> KV.CacheSpec:
    """Resolve a layer kind to its serving CacheSpec.  ``page_size > 0``
    turns would-be full caches into views over a shared paged arena
    (ring/recurrent layouts are already O(1) per slot and stay per-slot)."""
    if kind in ("attn", "local"):
        sink, window = A.kind_sink_window(cfg, kind, rt.serve_sparse)
        if sink < A.FULL_SINK:
            return KV.CacheSpec("ring", batch, sink=sink, window=window,
                                dtype=dtype)
        if page_size > 0:
            return KV.CacheSpec("paged", batch, max_len=max_len,
                                page_size=page_size, num_pages=num_pages,
                                dtype=dtype)
        return KV.CacheSpec("full", batch, max_len=max_len, dtype=dtype)
    if kind in ("mamba", "rwkv", "gla"):
        return KV.CacheSpec(kind, batch)
    raise ValueError(kind)


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     rt: Runtime, dtype=jnp.bfloat16, *, page_size: int = 0,
                     num_pages: int = 0):
    return KV.init_cache(cfg, layer_cache_spec(
        cfg, kind, batch, max_len, rt, dtype, page_size=page_size,
        num_pages=num_pages))


# --------------------------------------------------------------------------
# stack drivers (scan over groups + unrolled tail)
# --------------------------------------------------------------------------

def _maybe_remat(f, cfg):
    return jax.checkpoint(f) if cfg.remat else f


def stack_train(params: dict, cfg: ModelConfig, x: jax.Array, rt: Runtime):
    pat = cfg.layer_pattern
    shared = params["shared"]

    if params["stacked"] is not None:
        def group(x, gp):
            for j, kind in enumerate(pat):
                x = block_train(gp[j], cfg, x, kind, shared, rt)
            return x, None
        x, _ = jax.lax.scan(_maybe_remat(group, cfg), x, params["stacked"])
    start = cfg.n_layers - len(params["tail"])
    for i, bp in enumerate(params["tail"]):
        kind = cfg.layer_kinds()[start + i]
        f = (lambda bp_, x_, kind_=kind:
             block_train(bp_, cfg, x_, kind_, shared, rt))
        x = (jax.checkpoint(f) if cfg.remat else f)(bp, x)
    return x


def stack_prefill(params: dict, cfg: ModelConfig, x: jax.Array, rt: Runtime,
                  max_len: int):
    pat = cfg.layer_pattern
    shared = params["shared"]
    b = x.shape[0]

    caches_stacked = None
    if params["stacked"] is not None:
        def group(x, gp):
            caches = []
            for j, kind in enumerate(pat):
                x, c = block_prefill(gp[j], cfg, x, kind, shared, rt, b, max_len)
                caches.append(c)
            return x, tuple(caches)
        x, caches_stacked = jax.lax.scan(_maybe_remat(group, cfg), x,
                                         params["stacked"])
    tail_caches = []
    start = cfg.n_layers - len(params["tail"])
    for i, bp in enumerate(params["tail"]):
        kind = cfg.layer_kinds()[start + i]
        x, c = block_prefill(bp, cfg, x, kind, shared, rt, b, max_len)
        tail_caches.append(c)
    return x, {"stacked": caches_stacked, "tail": tuple(tail_caches)}


def stack_decode(params: dict, cfg: ModelConfig, x: jax.Array, caches: dict,
                 t, rt: Runtime, page_table: jax.Array | None = None):
    pat = cfg.layer_pattern
    shared = params["shared"]

    new_stacked = None
    if params["stacked"] is not None:
        def group(x, xs):
            gp, gc = xs
            ncs = []
            for j, kind in enumerate(pat):
                # page_table is closure-captured: one shared (B, pages) table
                # is loop-invariant across scan groups (each group's paged
                # arena is a distinct leaf of gc)
                x, nc = block_decode(gp[j], cfg, x, kind, gc[j], t, shared,
                                     rt, page_table)
                ncs.append(nc)
            return x, tuple(ncs)
        x, new_stacked = jax.lax.scan(group, x,
                                      (params["stacked"], caches["stacked"]))
    new_tail = []
    start = cfg.n_layers - len(params["tail"])
    for i, bp in enumerate(params["tail"]):
        kind = cfg.layer_kinds()[start + i]
        x, nc = block_decode(bp, cfg, x, kind, caches["tail"][i], t, shared,
                             rt, page_table)
        new_tail.append(nc)
    return x, {"stacked": new_stacked, "tail": tuple(new_tail)}
