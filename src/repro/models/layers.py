"""Shared model layers: norms, RoPE, embeddings, init helpers.

All models are functional: params are nested dicts of arrays, apply functions
are pure.  Leaf names follow the sharding conventions consumed by
repro.distributed.sharding (wq/wk/wv/w_in/w_gate = column-parallel,
wo/w_out = row-parallel, embed = vocab-parallel, …).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "rmsnorm", "init_rmsnorm", "rope", "apply_rope", "softcap",
    "dense_init", "embed_init", "take_embed", "logits_from_embed",
    "ACT",
]

ACT = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def init_rmsnorm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def rope(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for absolute positions.  positions: (...,) int."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., L, H, D); cos/sin: (..., L, D/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


def dense_init(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32,
               scale: float | None = None) -> jax.Array:
    s = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def take_embed(embed: jax.Array, tokens: jax.Array, *, scale: bool = False) -> jax.Array:
    x = jnp.take(embed, tokens, axis=0)
    if scale:  # gemma-style sqrt(d) input scaling
        x = x * jnp.asarray(x.shape[-1] ** 0.5, x.dtype)
    return x


def logits_from_embed(embed: jax.Array, x: jax.Array,
                      cap: float | None = None) -> jax.Array:
    lg = jnp.einsum("...d,vd->...v", x, embed.astype(x.dtype))
    return softcap(lg.astype(jnp.float32), cap)
