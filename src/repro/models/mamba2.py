"""Mamba2 (SSD) block — chunked state-space duality form.

Per head h with scalar decay a_t = exp(dt_t * A_h)  (A_h < 0):

    S_t = a_t S_{t-1} + dt_t * x_t ⊗ B_t           S: (hd, N)
    y_t = S_t C_t + D_h x_t

Chunked computation (chunk c): intra-chunk is an attention-like masked
matmul with decay weights exp(La_t - La_s); inter-chunk flows through the
carried state — same scheme as linear_attn but with scalar-per-head decay
and (B_t, C_t) playing (k, v) roles.  All projections are TENET ternary
linears; conv is a width-4 depthwise causal conv.

Decode replays the SAME chunk grid (anchored at position 0) instead of the
naive stepwise recurrence: the state carries the last full-chunk boundary
plus per-token buffers for the partial chunk, and each step recomputes its
row of the chunked einsums.  Stepwise state accumulation reassociates the
fp sums differently from the chunked prefill, and under ternary+DAS
quantization that ~1e-7 drift compounds across steps into discrete
rounding flips (the old zamba2 prefill/decode divergence); replaying the
chunk keeps decode on the prefill grid, so the error floor stays at
single-op noise with no accumulation.  Cost is O(chunk) per token — the
same order as an LPSA window decode — and memory is O(chunk), still
constant in context length.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SsmConfig
from repro.models import layers as L
from repro.models.ternary_linear import tlin_apply, tlin_compact, tlin_init

__all__ = ["mamba_init", "mamba_train", "mamba_decode", "mamba_dims"]


def mamba_dims(cfg: ModelConfig) -> tuple[int, int]:
    s: SsmConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    return d_inner, d_inner // s.head_dim


def mamba_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    s: SsmConfig = cfg.ssm
    d = cfg.d_model
    di, nh = mamba_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "wz": tlin_init(ks[0], d, di, dtype),
        "wx": tlin_init(ks[1], d, di, dtype),
        "wb": L.dense_init(ks[2], d, s.state_dim, dtype),
        "wc": L.dense_init(ks[3], d, s.state_dim, dtype),
        "wdt": L.dense_init(ks[4], d, nh, dtype),
        "dt_bias": jnp.zeros((nh,), dtype),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)).astype(dtype),
        "d_skip": jnp.ones((nh,), dtype),
        "conv": (jax.random.normal(ks[5], (s.conv_width, di), jnp.float32)
                 * 0.2).astype(dtype),
        "norm": L.init_rmsnorm(di, dtype),
        "wo": tlin_init(ks[6], di, d, dtype,
                        scale=(di * 2 * cfg.n_layers) ** -0.5),
    }


def _proj(p, cfg, x, kernel_mode):
    tc = cfg.ternary
    # wz/wx share the block input: one DAS compaction feeds both on the
    # fused packed serving path (no-op in training / ref modes)
    ca = tlin_compact(x, tc, p["wz"], kernel_mode=kernel_mode)
    z = tlin_apply(p["wz"], x, tc, kernel_mode=kernel_mode, ca=ca)
    xs = tlin_apply(p["wx"], x, tc, kernel_mode=kernel_mode, ca=ca)
    bmat = jnp.einsum("...d,dn->...n", x, p["wb"].astype(x.dtype))
    cmat = jnp.einsum("...d,dn->...n", x, p["wc"].astype(x.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("...d,dh->...h", x.astype(jnp.float32),
                   p["wdt"].astype(jnp.float32)) + p["dt_bias"].astype(jnp.float32))
    return z, xs, bmat, cmat, dt


def _conv_full(p, xs):
    """Causal depthwise conv over (B, L, di)."""
    w = p["conv"].astype(jnp.float32)                    # (cw, di)
    cw = w.shape[0]
    xp = jnp.pad(xs.astype(jnp.float32), ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + xs.shape[1], :] * w[i] for i in range(cw))
    return jax.nn.silu(out).astype(xs.dtype)


def _ssd_chunk(s_in, xb, bb, cb, dtb, la, causal):
    """One SSD chunk (any width c): (y (B,c,nh,hd), s_out (B,nh,hd,N))."""
    cla = jnp.cumsum(la, axis=1)                       # (B, c, nh)
    # pairwise decay exp(cla_t - cla_s); clamp the *difference* at 0 so
    # masked (t < s) entries can't overflow — cla itself stays exact.
    decay = jnp.exp(jnp.minimum(cla[:, :, None, :] - cla[:, None, :, :],
                                0.0))                  # (B,t,s,nh)
    scores = jnp.einsum("btn,bsn->bts", cb, bb)[:, :, :, None] * decay
    scores = jnp.where(causal[None, :, :, None], scores, 0.0)
    scores = scores * dtb[:, None, :, :]               # dt_s factor
    y = jnp.einsum("btsh,bshd->bthd", scores, xb)      # intra
    y += jnp.exp(cla)[:, :, :, None] * jnp.einsum(
        "bhdn,btn->bthd", s_in, cb)                    # inter
    la_end = cla[:, -1:, :]
    # B_s weighted by remaining decay and dt_s  -> (B, c, nh, N)
    b_state = (jnp.exp(la_end - cla) * dtb)[..., None] * bb[:, :, None, :]
    s_out = (jnp.exp(la_end)[:, 0, :, None, None] * s_in
             + jnp.einsum("bshd,bshn->bhdn", xb, b_state))
    return y, s_out


def init_ssd_buffers(cfg: ModelConfig, batch: int) -> dict:
    """Zeroed partial-chunk token buffers for chunk-replay decode."""
    s: SsmConfig = cfg.ssm or SsmConfig()
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    return {
        "ssd_x": jnp.zeros((batch, s.chunk, nh, s.head_dim), jnp.float32),
        "ssd_b": jnp.zeros((batch, s.chunk, s.state_dim), jnp.float32),
        "ssd_c": jnp.zeros((batch, s.chunk, s.state_dim), jnp.float32),
        "ssd_dt": jnp.zeros((batch, s.chunk, nh), jnp.float32),
    }


def mamba_train(p: dict, cfg: ModelConfig, x: jax.Array, *,
                kernel_mode: str = "ref",
                s0: jax.Array | None = None, conv0: jax.Array | None = None,
                return_state: bool = False):
    """Full-sequence SSD.  x: (B, L, D) -> (y (B,L,D), (S_fin, conv_tail)).

    With ``return_state`` the second element is instead the full decode
    state dict: conv tail, the ssm carry at the last *full* chunk boundary
    (position (L // chunk) * chunk), and the partial-chunk token buffers
    holding the remainder — exactly what :func:`mamba_decode` consumes to
    continue on the same chunk grid from position L.
    """
    s: SsmConfig = cfg.ssm
    b, l, d = x.shape
    di, nh = mamba_dims(cfg)
    z, xs, bmat, cmat, dt = _proj(p, cfg, x, kernel_mode)
    if conv0 is not None:
        xs_ext = jnp.concatenate([conv0.astype(xs.dtype), xs], axis=1)
        xs_conv = _conv_full(p, xs_ext)[:, conv0.shape[1]:]
    else:
        xs_conv = _conv_full(p, xs)
    conv_tail = (jnp.concatenate([conv0, xs], axis=1)[:, -(s.conv_width - 1):]
                 if conv0 is not None else xs[:, -(s.conv_width - 1):])
    xh = xs_conv.reshape(b, l, nh, s.head_dim)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))          # (nh,)
    log_a = dt * a[None, None, :]                         # (B, L, nh) <= 0

    if s0 is None:
        s0 = jnp.zeros((b, nh, s.head_dim, s.state_dim), jnp.float32)

    n_full, rem = divmod(l, s.chunk)
    f32 = jnp.float32
    seq = (xh.astype(f32), bmat.astype(f32), cmat.astype(f32),
           dt.astype(f32), log_a.astype(f32))

    def run_chunks(s_in, parts, c):
        """Scan chunks of width c over boundary-aligned ``parts``."""
        n = parts[0].shape[1] // c
        ch = lambda t: t.reshape((b, n, c) + t.shape[2:]).swapaxes(0, 1)  # noqa: E731
        causal = jnp.tril(jnp.ones((c, c), bool))
        step = lambda carry, blk: _ssd_chunk(carry, *blk, causal)[::-1]  # noqa: E731
        s_out, yc = jax.lax.scan(step, s_in, tuple(ch(t) for t in parts))
        return yc.swapaxes(0, 1).reshape(b, n * c, nh, s.head_dim), s_out

    if n_full == 0 or rem == 0:
        # whole sequence on one grid: chunk width min(s.chunk, l)
        y, s_fin = run_chunks(s0, seq, min(s.chunk, l) if l else 1)
        s_bound = s0 if n_full == 0 else s_fin
    else:
        split = n_full * s.chunk
        y_full, s_bound = run_chunks(s0, tuple(t[:, :split] for t in seq),
                                     s.chunk)
        y_rem, s_fin = run_chunks(s_bound, tuple(t[:, split:] for t in seq),
                                  rem)
        y = jnp.concatenate([y_full, y_rem], axis=1)

    y = y + p["d_skip"].astype(f32)[None, None, :, None] * xh.astype(f32)
    y = y.reshape(b, l, di).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = tlin_apply(p["wo"], y, cfg.ternary, kernel_mode=kernel_mode)
    if not return_state:
        return out, (s_fin, conv_tail)
    buf = init_ssd_buffers(cfg, b)
    if rem:   # n_full == 0 implies rem == l: the whole prefix is buffered
        tail = slice(l - rem, l)
        buf = {"ssd_x": buf["ssd_x"].at[:, :rem].set(seq[0][:, tail]),
               "ssd_b": buf["ssd_b"].at[:, :rem].set(seq[1][:, tail]),
               "ssd_c": buf["ssd_c"].at[:, :rem].set(seq[2][:, tail]),
               "ssd_dt": buf["ssd_dt"].at[:, :rem].set(seq[3][:, tail])}
    state = {"conv": conv_tail.astype(f32), "ssm": s_bound, **buf}
    return out, state


def mamba_decode(p: dict, cfg: ModelConfig, x: jax.Array, state: dict, t, *,
                 kernel_mode: str = "ref"):
    """One token at position(s) t.  x: (B, 1, D); state holds the conv tail,
    the ssm carry at the last full-chunk boundary, and partial-chunk buffers
    (see init_cache layout "mamba").

    t is a scalar or (B,) absolute position; ``slot = t % chunk`` addresses
    the buffers, so sequences at different depths batch together.  The step
    writes this token's (x, B, C, dt) into the buffers, recomputes its row
    of the prefill chunk einsums (same grid, same operand values -> error
    stays at single-op noise, never accumulating across steps), and folds
    the buffer into the carried state with the exact chunk formula when the
    chunk fills.
    """
    s: SsmConfig = cfg.ssm
    b = x.shape[0]
    di, nh = mamba_dims(cfg)
    c = s.chunk
    z, xs, bmat, cmat, dt = _proj(p, cfg, x, kernel_mode)
    conv_in = jnp.concatenate([state["conv"].astype(xs.dtype), xs], axis=1)
    w = p["conv"].astype(jnp.float32)
    xc = jax.nn.silu(jnp.einsum("bld,ld->bd", conv_in.astype(jnp.float32), w))
    new_conv = conv_in[:, 1:]
    xh = xc.reshape(b, nh, s.head_dim).astype(jnp.float32)

    t = jnp.asarray(t, jnp.int32)
    if t.ndim == 0:
        t = jnp.broadcast_to(t, (b,))
    slot = jnp.maximum(t, 0) % c                           # (B,)
    bidx = jnp.arange(b)
    xb = state["ssd_x"].at[bidx, slot].set(xh)
    bb = state["ssd_b"].at[bidx, slot].set(bmat[:, 0].astype(jnp.float32))
    cb = state["ssd_c"].at[bidx, slot].set(cmat[:, 0].astype(jnp.float32))
    dtb = state["ssd_dt"].at[bidx, slot].set(dt[:, 0])

    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    la = dtb * a[None, None, :]                            # (B, c, nh)
    cla = jnp.cumsum(la, axis=1)
    s_in = state["ssm"]
    # row `slot` of the chunk einsums (buffer rows past slot are zero)
    cla_p = cla[bidx, slot]                                # (B, nh)
    decay = jnp.exp(jnp.minimum(cla_p[:, None, :] - cla, 0.0))
    scores = jnp.einsum("bn,bsn->bs", cb[bidx, slot], bb)[:, :, None] * decay
    scores = jnp.where((jnp.arange(c)[None, :] <= slot[:, None])[:, :, None],
                       scores, 0.0)
    scores = scores * dtb
    y = jnp.einsum("bsh,bshd->bhd", scores, xb)
    y += jnp.exp(cla_p)[:, :, None] * jnp.einsum("bhdn,bn->bhd", s_in,
                                                 cb[bidx, slot])
    # chunk boundary: fold the full buffer into the carried state and clear
    la_end = cla[:, -1:, :]
    b_state = (jnp.exp(la_end - cla) * dtb)[..., None] * bb[:, :, None, :]
    s_folded = (jnp.exp(la_end)[:, 0, :, None, None] * s_in
                + jnp.einsum("bshd,bshn->bhdn", xb, b_state))
    full = slot == c - 1                                   # (B,)
    s_new = jnp.where(full[:, None, None, None], s_folded, s_in)

    def keep(buf):
        m = full.reshape((b,) + (1,) * (buf.ndim - 1))
        return jnp.where(m, jnp.zeros_like(buf), buf)

    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = tlin_apply(p["wo"], y, cfg.ternary, kernel_mode=kernel_mode)
    return out, {"conv": new_conv, "ssm": s_new, "ssd_x": keep(xb),
                 "ssd_b": keep(bb), "ssd_c": keep(cb), "ssd_dt": keep(dtb)}
