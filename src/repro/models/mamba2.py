"""Mamba2 (SSD) block — chunked state-space duality form.

Per head h with scalar decay a_t = exp(dt_t * A_h)  (A_h < 0):

    S_t = a_t S_{t-1} + dt_t * x_t ⊗ B_t           S: (hd, N)
    y_t = S_t C_t + D_h x_t

Chunked computation (chunk c): intra-chunk is an attention-like masked
matmul with decay weights exp(La_t - La_s); inter-chunk flows through the
carried state — same scheme as linear_attn but with scalar-per-head decay
and (B_t, C_t) playing (k, v) roles.  All projections are TENET ternary
linears; conv is a width-4 depthwise causal conv.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SsmConfig
from repro.models import layers as L
from repro.models.ternary_linear import tlin_apply, tlin_compact, tlin_init

__all__ = ["mamba_init", "mamba_train", "mamba_decode", "mamba_dims"]


def mamba_dims(cfg: ModelConfig) -> tuple[int, int]:
    s: SsmConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    return d_inner, d_inner // s.head_dim


def mamba_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    s: SsmConfig = cfg.ssm
    d = cfg.d_model
    di, nh = mamba_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "wz": tlin_init(ks[0], d, di, dtype),
        "wx": tlin_init(ks[1], d, di, dtype),
        "wb": L.dense_init(ks[2], d, s.state_dim, dtype),
        "wc": L.dense_init(ks[3], d, s.state_dim, dtype),
        "wdt": L.dense_init(ks[4], d, nh, dtype),
        "dt_bias": jnp.zeros((nh,), dtype),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)).astype(dtype),
        "d_skip": jnp.ones((nh,), dtype),
        "conv": (jax.random.normal(ks[5], (s.conv_width, di), jnp.float32)
                 * 0.2).astype(dtype),
        "norm": L.init_rmsnorm(di, dtype),
        "wo": tlin_init(ks[6], di, d, dtype,
                        scale=(di * 2 * cfg.n_layers) ** -0.5),
    }


def _proj(p, cfg, x, kernel_mode):
    tc = cfg.ternary
    # wz/wx share the block input: one DAS compaction feeds both on the
    # fused packed serving path (no-op in training / ref modes)
    ca = tlin_compact(x, tc, p["wz"], kernel_mode=kernel_mode)
    z = tlin_apply(p["wz"], x, tc, kernel_mode=kernel_mode, ca=ca)
    xs = tlin_apply(p["wx"], x, tc, kernel_mode=kernel_mode, ca=ca)
    bmat = jnp.einsum("...d,dn->...n", x, p["wb"].astype(x.dtype))
    cmat = jnp.einsum("...d,dn->...n", x, p["wc"].astype(x.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("...d,dh->...h", x.astype(jnp.float32),
                   p["wdt"].astype(jnp.float32)) + p["dt_bias"].astype(jnp.float32))
    return z, xs, bmat, cmat, dt


def _conv_full(p, xs):
    """Causal depthwise conv over (B, L, di)."""
    w = p["conv"].astype(jnp.float32)                    # (cw, di)
    cw = w.shape[0]
    xp = jnp.pad(xs.astype(jnp.float32), ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + xs.shape[1], :] * w[i] for i in range(cw))
    return jax.nn.silu(out).astype(xs.dtype)


def mamba_train(p: dict, cfg: ModelConfig, x: jax.Array, *,
                kernel_mode: str = "ref",
                s0: jax.Array | None = None, conv0: jax.Array | None = None):
    """Full-sequence SSD.  x: (B, L, D) -> (y (B,L,D), (S_fin, conv_tail))."""
    s: SsmConfig = cfg.ssm
    b, l, d = x.shape
    di, nh = mamba_dims(cfg)
    z, xs, bmat, cmat, dt = _proj(p, cfg, x, kernel_mode)
    if conv0 is not None:
        xs_ext = jnp.concatenate([conv0.astype(xs.dtype), xs], axis=1)
        xs_conv = _conv_full(p, xs_ext)[:, conv0.shape[1]:]
    else:
        xs_conv = _conv_full(p, xs)
    conv_tail = (jnp.concatenate([conv0, xs], axis=1)[:, -(s.conv_width - 1):]
                 if conv0 is not None else xs[:, -(s.conv_width - 1):])
    xh = xs_conv.reshape(b, l, nh, s.head_dim)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))          # (nh,)
    log_a = dt * a[None, None, :]                         # (B, L, nh) <= 0

    c = min(s.chunk, l)
    if l % c:
        c = l
    n = l // c
    ch = lambda t, shp: t.reshape((b, n, c) + shp).swapaxes(0, 1)  # noqa: E731
    xc = ch(xh, (nh, s.head_dim))
    bc = ch(bmat, (s.state_dim,))
    cc = ch(cmat, (s.state_dim,))
    dtc = ch(dt, (nh,))
    lac = ch(log_a, (nh,))
    causal = jnp.tril(jnp.ones((c, c), bool))

    if s0 is None:
        s0 = jnp.zeros((b, nh, s.head_dim, s.state_dim), jnp.float32)

    def step(carry, blk):
        s_in = carry
        xb, bb, cb, dtb, la = (t.astype(jnp.float32) for t in blk)
        cla = jnp.cumsum(la, axis=1)                       # (B, c, nh)
        # pairwise decay exp(cla_t - cla_s); clamp the *difference* at 0 so
        # masked (t < s) entries can't overflow — cla itself stays exact.
        decay = jnp.exp(jnp.minimum(cla[:, :, None, :] - cla[:, None, :, :],
                                    0.0))                  # (B,t,s,nh)
        scores = jnp.einsum("btn,bsn->bts", cb, bb)[:, :, :, None] * decay
        scores = jnp.where(causal[None, :, :, None], scores, 0.0)
        scores = scores * dtb[:, None, :, :]               # dt_s factor
        y = jnp.einsum("btsh,bshd->bthd", scores, xb)      # intra
        y += jnp.exp(cla)[:, :, :, None] * jnp.einsum(
            "bhdn,btn->bthd", s_in, cb)                    # inter
        la_end = cla[:, -1:, :]
        # B_s weighted by remaining decay and dt_s  -> (B, c, nh, N)
        b_state = (jnp.exp(la_end - cla) * dtb)[..., None] * bb[:, :, None, :]
        s_out = (jnp.exp(la_end)[:, 0, :, None, None] * s_in
                 + jnp.einsum("bshd,bshn->bhdn", xb, b_state))
        return s_out, y

    s_fin, yc = jax.lax.scan(step, s0, (xc, bc, cc, dtc, lac))
    y = yc.swapaxes(0, 1).reshape(b, l, nh, s.head_dim)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, l, di).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = tlin_apply(p["wo"], y, cfg.ternary, kernel_mode=kernel_mode)
    return out, (s_fin, conv_tail)


def mamba_decode(p: dict, cfg: ModelConfig, x: jax.Array, state: dict, *,
                 kernel_mode: str = "ref"):
    """One token.  x: (B, 1, D); state {"conv": (B, cw-1, di), "ssm": ...}."""
    s: SsmConfig = cfg.ssm
    b = x.shape[0]
    di, nh = mamba_dims(cfg)
    z, xs, bmat, cmat, dt = _proj(p, cfg, x, kernel_mode)
    conv_in = jnp.concatenate([state["conv"].astype(xs.dtype), xs], axis=1)
    w = p["conv"].astype(jnp.float32)
    xc = jax.nn.silu(jnp.einsum("bld,ld->bd", conv_in.astype(jnp.float32), w))
    new_conv = conv_in[:, 1:]
    xh = xc.reshape(b, nh, s.head_dim).astype(jnp.float32)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    la = dt[:, 0] * a[None, :]                             # (B, nh)
    ssm = state["ssm"]
    s_new = (jnp.exp(la)[:, :, None, None] * ssm
             + dt[:, 0][:, :, None, None] * xh[..., None]
             * bmat[:, 0][:, None, None, :].astype(jnp.float32))
    y = jnp.einsum("bhdn,bn->bhd", s_new, cmat[:, 0].astype(jnp.float32))
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = tlin_apply(p["wo"], y, cfg.ternary, kernel_mode=kernel_mode)
    return out, {"conv": new_conv, "ssm": s_new}
