"""GQA attention with TENET ternary projections + LPSA / local / full modes.

Layer kinds (configs.base.layer_pattern):
  "attn"  — global attention: full causal, or sink+window when cfg.lpsa set
  "local" — sliding-window attention (window = cfg.window, no sink)

Three execution paths share one set of (ternary) projection weights:
  * train / full-prefill: chunked flash attention in pure JAX (differentiable,
    O(L·bk) live memory — scores never materialize globally),
  * streaming prefill: core.lpsa.lpsa_prefill (pack-fused, Algorithm 1),
  * decode: one-token attention against a full or ring KV cache.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import lpsa as lpsa_lib
from repro.kernels import ops
from repro.models import layers as L
from repro.models.ternary_linear import tlin_apply, tlin_compact, tlin_init

__all__ = [
    "attn_init", "qkv_project", "flash_masked", "attn_train",
    "attn_prefill_streaming", "attn_decode", "kind_sink_window",
]

NEG_INF = -1e30
FULL_SINK = 1 << 30   # sink larger than any position == full causal


def kind_sink_window(cfg: ModelConfig, kind: str, serve_sparse: bool) -> tuple[int, int]:
    """(sink, window) for a layer kind.  serve_sparse toggles LPSA on globals."""
    if kind == "local":
        return 0, cfg.window
    if cfg.lpsa is not None and serve_sparse:
        return cfg.lpsa.sink, cfg.lpsa.window
    return FULL_SINK, 0


def attn_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return {
        "wq": tlin_init(ks[0], d, qd, dtype),
        "wk": tlin_init(ks[1], d, kvd, dtype),
        "wv": tlin_init(ks[2], d, kvd, dtype),
        "wo": tlin_init(ks[3], qd, d, dtype, scale=(qd * 2 * cfg.n_layers) ** -0.5),
    }


def qkv_project(p: dict, cfg: ModelConfig, x: jax.Array, *,
                kernel_mode: str = "ref"):
    """(B, L, D) -> q (B,L,Hq,Dh), k/v (B,L,Hkv,Dh) through ternary linears.

    On the fused DAS serving path the block top-k (the paper's ASM) runs
    once per token and the compacted stream feeds all three projections."""
    b, l, _ = x.shape
    tc = cfg.ternary
    ca = tlin_compact(x, tc, p["wq"], kernel_mode=kernel_mode)
    q = tlin_apply(p["wq"], x, tc, kernel_mode=kernel_mode, ca=ca)
    k = tlin_apply(p["wk"], x, tc, kernel_mode=kernel_mode, ca=ca)
    v = tlin_apply(p["wv"], x, tc, kernel_mode=kernel_mode, ca=ca)
    hd = cfg.head_dim_
    return (q.reshape(b, l, cfg.n_heads, hd),
            k.reshape(b, l, cfg.n_kv_heads, hd),
            v.reshape(b, l, cfg.n_kv_heads, hd))


def _rope_fn(cfg: ModelConfig):
    def f(x, pos):
        cos, sin = L.rope(pos, cfg.head_dim_, cfg.rope_theta)
        return L.apply_rope(x, cos, sin)
    return f


def flash_masked(q, k, v, q_pos, k_pos, *, sink: int, window: int,
                 softcap: float | None = None, kv_chunk: int = 512) -> jax.Array:
    """Differentiable chunked flash attention with the LPSA mask family.

    q: (B, Lq, Hq, D); k, v: (B, Lk, Hkv, D); q_pos (Lq,) or per-sequence
    (B, Lq); k_pos (Lk,) or (B, Lk).  Per-sequence positions let each batch
    row sit at its own decode depth (continuous batching); 1-D positions
    broadcast to the whole batch (lock-step).  Scans KV chunks with an
    online softmax; per-step live memory is O(Lq * kv_chunk) — the XLA
    analogue of the Pallas kernel.
    """
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    n_rep = hq // hkv
    c = min(kv_chunk, lk)
    if lk % c:
        c = lk  # fall back to a single chunk for awkward cache sizes
    scale = d ** -0.5
    q_pos = jnp.broadcast_to(jnp.atleast_2d(q_pos), (b, lq))
    k_pos = jnp.broadcast_to(jnp.atleast_2d(k_pos), (b, lk))
    qh = jnp.swapaxes(q, 1, 2).astype(jnp.float32)       # (B,Hq,Lq,D)
    kc = k.reshape(b, lk // c, c, hkv, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, lk // c, c, hkv, d).transpose(1, 0, 3, 2, 4)
    kpc = k_pos.reshape(b, lk // c, c).swapaxes(0, 1)    # (N, B, c)

    def step(carry, blk):
        m, l, acc = carry
        kb, vb, kp = blk                                  # (B,Hkv,c,D), (B,c)
        kb = jnp.repeat(kb, n_rep, axis=1).astype(jnp.float32)
        vb = jnp.repeat(vb, n_rep, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qh, kb) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        mask = lpsa_lib.lpsa_allowed(q_pos[:, :, None], kp[:, None, :],
                                     sink, window)
        mask = mask & (kp >= 0)[:, None, :]               # (B,Lq,c)
        s = jnp.where(mask[:, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.where(m_new <= NEG_INF, 0.0, m_new)
        p = jnp.where(mask[:, None], jnp.exp(s - m_safe), 0.0)
        alpha = jnp.where(m <= NEG_INF, 0.0, jnp.exp(m - m_safe))
        l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, hq, lq, 1), NEG_INF, jnp.float32),
            jnp.zeros((b, hq, lq, 1), jnp.float32),
            jnp.zeros((b, hq, lq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init, (kc, vc, kpc))
    out = acc / jnp.where(l == 0.0, 1.0, l)
    return jnp.swapaxes(out, 1, 2).astype(q.dtype)        # (B,Lq,Hq,D)


def attn_train(p: dict, cfg: ModelConfig, x: jax.Array, kind: str, *,
               serve_sparse: bool = True, kernel_mode: str = "ref") -> jax.Array:
    """Training / full-prefill attention over a whole sequence."""
    b, l, _ = x.shape
    sink, window = kind_sink_window(cfg, kind, serve_sparse)
    q, k, v = qkv_project(p, cfg, x, kernel_mode=kernel_mode)
    pos = jnp.arange(l)
    rp = _rope_fn(cfg)
    q, k = rp(q, pos), rp(k, pos)
    o = flash_masked(q, k, v, pos, pos, sink=sink, window=window,
                     softcap=cfg.attn_softcap)
    o = o.reshape(b, l, cfg.q_dim)
    return tlin_apply(p["wo"], o, cfg.ternary, kernel_mode=kernel_mode)


def attn_prefill_streaming(p: dict, cfg: ModelConfig, x: jax.Array, kind: str,
                           *, kernel_mode: str = "ref"):
    """LPSA Algorithm-1 prefill: fused pack-chunked projection + attention.

    Returns (y, stream_state) — the scan carry becomes the decode ring cache
    (models.kvcache.ring_from_stream).
    """
    sink, window = kind_sink_window(cfg, kind, True)
    if sink >= FULL_SINK:
        raise ValueError("streaming prefill needs a sparse pattern (lpsa/local)")
    spec = lpsa_lib.LpsaSpec(sink=sink, window=window,
                             chunk=cfg.lpsa.chunk if cfg.lpsa else 256)
    proj = partial(_stream_proj, p, cfg, kernel_mode)
    o, state = lpsa_lib.lpsa_prefill(
        x, proj, spec=spec, num_q_heads=cfg.n_heads,
        num_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
        rope=_rope_fn(cfg), softcap=cfg.attn_softcap, return_state=True)
    b, l = x.shape[0], x.shape[1]
    y = tlin_apply(p["wo"], o.reshape(b, l, cfg.q_dim), cfg.ternary,
                   kernel_mode=kernel_mode)
    return y, state


def _stream_proj(p, cfg, kernel_mode, pack):
    return qkv_project(p, cfg, pack, kernel_mode=kernel_mode)


def attn_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict,
                t: jax.Array, kind: str, *, serve_sparse: bool = True,
                kernel_mode: str = "ref",
                page_table: jax.Array | None = None):
    """One-token decode.  x: (B, 1, D); cache from models.kvcache.

    t: scalar (lock-step: all sequences at the same position) or (B,)
    per-sequence positions (continuous batching: each slot at its own
    decode depth).  Paged caches (kvcache.CacheSpec layout="paged") take
    ``page_table`` (B, pages_per_seq) int32 mapping each sequence's logical
    pages to arena pages; the gathered view is laid out exactly like a full
    cache, so the attention math below is layout-oblivious.  For paged
    caches rows with t < 0 are inactive (their write is routed to the null
    page and masked).  Returns (y (B,1,D), new_cache).
    """
    from repro.models import kvcache  # local import to avoid cycle

    b = x.shape[0]
    sink, window = kind_sink_window(cfg, kind, serve_sparse)
    q, k, v = qkv_project(p, cfg, x, kernel_mode=kernel_mode)
    t = jnp.asarray(t, jnp.int32)
    if t.ndim == 0:
        t = jnp.broadcast_to(t, (b,))
    pos = t[:, None]                                     # (B, 1)
    rp = _rope_fn(cfg)
    q, k = rp(q, pos), rp(k, pos)
    ring = sink < FULL_SINK
    cache = kvcache.attn_write(cache, k, v, t, sink=sink, window=window,
                               ring=ring, page_table=page_table)
    k_all, v_all, k_pos = kvcache.attn_read(cache, page_table)  # k_pos (B, S)
    o = _decode_attention(cfg, q, k_all, v_all, pos, k_pos, sink=sink,
                          window=window, kernel_mode=kernel_mode)
    o = o.reshape(b, 1, cfg.q_dim)
    return tlin_apply(p["wo"], o, cfg.ternary, kernel_mode=kernel_mode), cache


def _decode_attention(cfg: ModelConfig, q, k, v, q_pos, k_pos, *, sink: int,
                      window: int, kernel_mode: str) -> jax.Array:
    """Route the one-token attention step by kernel mode.

    q: (B, Lq, Hq, D); k, v: (B, Lk, Hkv, D); q_pos (B, Lq); k_pos (B, Lk).
    ``pallas``/``compiled`` go through the Pallas LPSA kernel; ``tuned``
    resolves the per-shape winner from the autotune cache — Pallas tiles
    where they compile, the chunked XLA flash (with the tuned kv-chunk)
    otherwise; everything else keeps `flash_masked`, which shares the
    decode step's per-token compaction budget with the ternary linears
    (one fused LPSA+DAS decode trace).
    """
    b, lq, hq, d = q.shape
    lk, hkv = k.shape[1], k.shape[2]
    kv_chunk = min(512, lk)
    tiles: dict = {}
    route_pallas = ops.attn_kernel_wanted(kernel_mode)
    if kernel_mode == "tuned":
        from repro.kernels import autotune
        tcfg = autotune.lookup(
            "sparse_attn", **autotune.attn_dims(hq=hq, hkv=hkv, lq=lq, lk=lk,
                                                d=d, sink=sink, window=window))
        if tcfg.impl == "pallas":
            route_pallas = True
            tiles = {"block_q": tcfg.block_m or 128,
                     "block_k": tcfg.block_k or 128}
        else:   # xla_flash winner (or interpret/ref: emulated per-token
            # attention is pathological — keep the XLA flash path)
            kv_chunk = tcfg.block_k or kv_chunk
    if route_pallas:
        o = ops.sparse_attention(
            q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2), q_pos,
            k_pos, sink=sink, window=window, softcap=cfg.attn_softcap,
            mode="pallas" if kernel_mode == "tuned" else kernel_mode, **tiles)
        return o.swapaxes(1, 2)
    return flash_masked(q, k, v, q_pos, k_pos, sink=sink, window=window,
                        softcap=cfg.attn_softcap, kv_chunk=kv_chunk)
