"""Workload-intrinsic roofline terms per (arch x shape x mesh) cell.

Why this exists: XLA's `cost_analysis()` visits every `while` body ONCE, so
any scan (layer scan, LPSA pack scan, flash kv scan, SSD chunk scan)
undercounts, while its op-level "bytes accessed" overcounts HBM traffic
(fusion-internal operands).  The dry-run reconstructs the layer scan from
unrolled compiles (launch.dryrun), but inner scans remain; this module
derives the three roofline terms from first principles — the same arithmetic
a roofline analysis would do on paper — and the report shows both sources.

Counting conventions (documented in EXPERIMENTS.md §Roofline):
  * train flops factor = 8 x params x tokens with remat (2 fwd + 4 bwd +
    2 recompute), 6 without; serving = 2.
  * DAS does NOT discount flops: the lowered XLA path is masked-dense
    (the S_a FLOP cut needs the Pallas das kernel; reported as headroom).
  * attention keys/query: full = (L+1)/2 averaged, LPSA = TL_SA, local =
    window (exact row-average for short sequences).
  * activation HBM traffic: layer in/out + mixer internals, ~6 touches per
    token-layer forward (r/w of x, qkv/o or ssm streams), x2.5 for train
    (bwd reads saved + writes grads, remat recompute reads).
  * collectives: Megatron-TP 2 all-reduces per block (fwd; x2 more for bwd),
    EP psum per MoE block, ZeRO-1 reduce-scatter + all-gather of params,
    wire factor 2x for ring all-reduce.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec

__all__ = ["cell_analytic", "AnalyticCost"]

BYTES = {"bfloat16": 2, "float32": 4}


@dataclass(frozen=True)
class AnalyticCost:
    flops_per_dev: float
    hbm_bytes_per_dev: float
    coll_bytes_per_dev: float

    def terms(self, peak=197e12, hbm=819e9, link=50e9):
        return (self.flops_per_dev / peak, self.hbm_bytes_per_dev / hbm,
                self.coll_bytes_per_dev / link)


def _avg_keys(kind: str, cfg: ModelConfig, L: int, serve_sparse: bool,
              decode_ctx: int | None = None) -> float:
    """Average attended keys per query for a mixer kind."""
    if kind == "local":
        w = cfg.window
        return min(w, decode_ctx if decode_ctx else (w + 1) / 2 if L < w else w)
    if cfg.lpsa is not None and serve_sparse:
        tl = cfg.lpsa.tl_sa
        base = decode_ctx if decode_ctx else L
        return min(tl, base)
    return decode_ctx if decode_ctx else (L + 1) / 2


def _weight_bytes_per_param(cfg: ModelConfig, serving: bool) -> float:
    if not serving:
        return BYTES[cfg.dtype]
    if not cfg.ternary.enabled:
        return 2.0
    return {"packed": 0.2, "int8": 1.0, "bf16": 2.0}[cfg.ternary.serve_format]


def cell_analytic(cfg: ModelConfig, shape: ShapeSpec, n_dev: int,
                  model_shards: int = 16, *, serve_sparse: bool = True,
                  zero1: bool = True) -> AnalyticCost:
    B, L = shape.global_batch, shape.seq_len
    train = shape.kind == "train"
    decode = shape.kind == "decode"
    serving = not train
    act_b = BYTES[cfg.dtype]
    d = cfg.d_model
    kinds = cfg.layer_kinds()

    tokens = B * (1 if decode else L)
    f = (8.0 if cfg.remat else 6.0) if train else 2.0

    # ---- parameter counts ---------------------------------------------------
    n_linear_active = 0
    n_linear_total = 0
    for kind in kinds:
        if kind in ("attn", "local"):
            blk = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
        elif kind == "mamba":
            s = cfg.ssm
            di = s.expand * d
            blk = 2 * d * di + di * d + 2 * d * s.state_dim
        elif kind in ("rwkv", "gla"):
            blk = 5 * d * d + (2 * d * cfg.d_ff if kind == "rwkv" else 0)
        else:
            blk = 0
        n_linear_active += blk
        n_linear_total += blk
        if cfg.moe is not None and kind in ("attn", "local", "gla"):
            e = cfg.moe
            per_e = 3 * d * e.d_expert
            n_linear_active += (e.top_k + e.n_shared) * per_e + d * e.n_experts
            n_linear_total += (e.n_experts + e.n_shared) * per_e + d * e.n_experts
        elif kind in ("attn", "local", "gla") and cfg.moe is None:
            nf = (3 if cfg.ffn_kind == "gated" else 2) * d * cfg.d_ff
            n_linear_active += nf
            n_linear_total += nf
    n_embed = cfg.vocab_padded * d

    # ---- FLOPs ---------------------------------------------------------------
    flops = f * n_linear_active * tokens           # 2 MAC ops folded into f
    flops += f * n_embed * tokens                  # logits head (tied)
    for kind in kinds:
        if kind in ("attn", "local"):
            kq = _avg_keys(kind, cfg, L, serve_sparse,
                           decode_ctx=L if decode else None)
            flops += f * 2 * cfg.n_heads * cfg.head_dim_ * kq * tokens
        elif kind == "mamba":
            s = cfg.ssm
            di = s.expand * d
            nh = di // s.head_dim
            c = min(s.chunk, L if not decode else 1)
            flops += f * tokens * (c * nh * s.head_dim + 2 * di * s.state_dim)
        elif kind in ("rwkv", "gla"):
            hd = cfg.head_dim_
            c = 1 if decode else min(56, L)
            flops += f * tokens * cfg.n_heads * hd * (c + 2 * hd)
    flops_per_dev = flops / n_dev

    # ---- HBM bytes per device -------------------------------------------------
    wb = _weight_bytes_per_param(cfg, serving)
    weight_bytes = (n_linear_total * wb + n_embed * act_b) / model_shards
    # weights stream once per step from each device's HBM shard
    if train:
        # + grads f32 + 2 adam moments touched (ZeRO: sharded over data too)
        opt_touch = (n_linear_total + n_embed) * 4 * 3 / n_dev
    else:
        opt_touch = 0.0
    t_loc = tokens / max(1, n_dev // model_shards)  # tokens per model-replica
    act_touch = 6.0 * (2.5 if train else 1.0)
    act_bytes = t_loc * d * act_b * len(kinds) * act_touch
    kv_bytes = 0.0
    if decode:
        for kind in kinds:
            if kind in ("attn", "local"):
                kq = _avg_keys(kind, cfg, L, serve_sparse, decode_ctx=L)
                kv_bytes += (B / max(1, n_dev // model_shards)) * kq \
                    * cfg.kv_dim * 2 * 2 / 1  # read K+V bf16 over kept keys
            elif kind == "mamba":
                s = cfg.ssm
                di = s.expand * d
                kv_bytes += B * (di // s.head_dim) * s.head_dim * s.state_dim * 4 * 2
            elif kind in ("rwkv", "gla"):
                kv_bytes += B * cfg.n_heads * cfg.head_dim_ ** 2 * 4 * 2
    hbm = weight_bytes + opt_touch + act_bytes + kv_bytes

    # ---- collective bytes per device -------------------------------------------
    coll = 0.0
    ar_wire = 2.0
    n_tp_blocks = sum(1 for k in kinds)
    # activation all-reduces: 2 per block fwd (+2 bwd when training)
    coll += t_loc * d * act_b * n_tp_blocks * 2 * ar_wire * (2 if train else 1)
    if cfg.moe is not None:
        coll += t_loc * d * act_b * sum(
            1 for k in kinds if k in ("attn", "local")) * ar_wire  # EP psum
    if train:
        params_bytes = (n_linear_total + n_embed) * 4
        if zero1:
            coll += 2.0 * params_bytes / n_dev * 2  # RS grads + AG params
        else:
            coll += ar_wire * params_bytes / n_dev
    return AnalyticCost(flops_per_dev, hbm, coll)
