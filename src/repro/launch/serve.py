"""Serving driver: pjit-able step builders + a CLI over repro.serve.

`make_prefill_step` / `make_decode_step` are the pjit-able pure steps the
dry-run lowers at production shapes.  `main` is now a thin CLI over
`repro.serve.ServeEngine`: export ternary weights (TWD packing), submit a
staggered trace of generation requests, and let the continuous-batching
engine prefill/decode them through per-sequence KV state.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch bitnet-1.3b --reduced \
      --prompt-len 64 --gen 32 --requests 4 --stagger 4 --temperature 0.8
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduced_cfg
from repro.kernels.ops import KernelMode
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.serve import Request, ServeConfig, ServeEngine

__all__ = ["make_prefill_step", "make_decode_step", "build_engine", "main"]


def make_prefill_step(cfg, rt: Runtime, *, max_len: int):
    def prefill_step(sparams, inputs):
        return MD.prefill(sparams, cfg, inputs, rt, max_len=max_len)
    return prefill_step


def make_decode_step(cfg, rt: Runtime):
    def decode_step(sparams, caches, token, t):
        return MD.decode_step(sparams, cfg, caches, token, t, rt)
    return decode_step


def build_engine(cfg, rt: Runtime, config: ServeConfig | None = None,
                 **legacy) -> ServeEngine:
    """Init params, export TWD serving weights, wrap them in a ServeEngine.

    Pass ``config=ServeConfig(...)``; loose kwargs (max_slots, max_len, ...)
    are forwarded through the engine's deprecated back-compat shim."""
    seed = config.seed if config is not None else legacy.get("seed", 0)
    params = MD.init_params(jax.random.PRNGKey(seed), cfg)
    sparams = MD.export_serving(params, cfg)
    nbytes = sum(x.nbytes for x in jax.tree.leaves(sparams))
    mbytes = sum(x.nbytes for x in jax.tree.leaves(params))
    print(f"[serve] {cfg.name}: serving weights {nbytes/1e6:.1f} MB "
          f"(master {mbytes/1e6:.1f} MB, {mbytes/max(nbytes,1):.1f}x TWD+quant)")
    return ServeEngine(cfg, sparams, rt, config=config, **legacy)


def _make_prompt(cfg, rng, length: int):
    if MD.uses_embeds(cfg):
        return jnp.asarray(rng.standard_normal((length, cfg.d_model)),
                           jnp.float32)
    return np.asarray(rng.integers(0, cfg.vocab, (length,)), np.int32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bitnet-1.3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--stagger", type=int, default=0,
                    help="virtual decode steps between request arrivals")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--policy", choices=["continuous", "wave"],
                    default="continuous")
    ap.add_argument("--no-sparse", action="store_true",
                    help="full attention + full KV cache (naive baseline)")
    ap.add_argument("--layout", choices=["auto", "paged"], default="auto",
                    help="KV layout: 'auto' keeps per-slot caches; 'paged' "
                         "shares one refcounted page arena per full-attn "
                         "layer with lazy allocation + radix prefix sharing")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged layout)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="pool capacity incl. the null page; 0 auto-sizes "
                         "to the per-slot worst case")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable the radix-trie prompt-prefix index "
                         "(paged layout)")
    ap.add_argument("--kernel-mode", default="ref",
                    type=lambda s: KernelMode.parse(s).value,
                    help="ternary-linear execution path (kernels/ops."
                         "KERNEL_MODES); kernel modes route slab-aligned "
                         "packed+DAS layers through the fused "
                         "das_ternary_gemm datapath; 'tuned' autotunes "
                         "per-shape at engine construction and caches "
                         "winners on disk (see kernels/autotune.py)")
    ap.add_argument("--moe-expert-capacity", type=int, default=0,
                    help="bound the per-expert token load per decode tick "
                         "by deferring admissions (MoE configs only; 0 = "
                         "unbounded — decode itself never drops tokens)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # gate bad configs here with argparse-style errors instead of letting
    # them traceback deep inside cache/engine init
    try:
        cfg = get_config(args.arch)
    except KeyError as e:
        ap.error(str(e.args[0] if e.args else e))
    if args.reduced:
        cfg = reduced_cfg(cfg)
    rt = Runtime(serve_sparse=not args.no_sparse,
                 kernel_mode=args.kernel_mode)
    max_len = args.prompt_len + args.gen
    if args.layout == "paged" and max_len % args.page_size:
        max_len += args.page_size - max_len % args.page_size

    try:
        sc = ServeConfig(max_slots=args.slots, max_len=max_len,
                         layout=args.layout, page_size=args.page_size,
                         num_pages=args.num_pages,
                         prefix_sharing=not args.no_prefix_sharing,
                         top_k=args.top_k, seed=args.seed,
                         policy=args.policy,
                         moe_expert_capacity=args.moe_expert_capacity)
        eng = build_engine(cfg, rt, config=sc)
    except ValueError as e:
        ap.error(f"config not serveable: {e}")

    # the resolved slot-state union (one entry per distinct layout, in
    # stack order) — the README's "serving the model zoo" table, live
    layouts: dict[str, int] = {}
    for row in eng.layout_summary():
        layouts[row["layout"]] = layouts.get(row["layout"], 0) + 1
    print("[serve] slot-state layouts: "
          + ", ".join(f"{k} x{v}" for k, v in layouts.items()))

    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        eng.submit(Request(uid=i, prompt=_make_prompt(cfg, rng, args.prompt_len),
                           max_new_tokens=args.gen,
                           temperature=args.temperature,
                           arrival=i * args.stagger))
    results = eng.run()

    st = eng.stats
    print(f"[serve] {st.decode_steps} decode steps, slot utilization "
          f"{st.slot_utilization:.2f}, {st.generated_tokens} tokens in "
          f"{st.wall_seconds:.2f}s "
          f"({st.generated_tokens/max(st.wall_seconds,1e-9):.1f} tok/s)")
    if args.layout == "paged":
        pool = eng.pool_stats()
        if pool["num_pages"]:
            print(f"[serve] paged pool: {pool['pages_peak']}/"
                  f"{pool['num_pages']} pages peak "
                  f"({pool['bytes_peak']/1e6:.2f} MB vs dense "
                  f"{pool['dense_equiv_bytes']/1e6:.2f} MB), "
                  f"{st.prefix_hits} prefix hits "
                  f"({st.prompt_tokens_reused} tokens reused), "
                  f"{st.cow_copies} CoW copies")
        else:
            print("[serve] paged pool: no full-attention layers under this "
                  "config (LPSA/ring only) -> no page arenas; pass "
                  "--no-sparse to page the global layers")
    for uid in sorted(results):
        r = results[uid]
        print(f"[serve] req {uid}: ttft {r.ttft_steps} steps, latency "
              f"{r.latency_steps} steps, ids {r.tokens[:8].tolist()}...")
    return results


if __name__ == "__main__":
    main()
