"""Serving driver: prefill/decode step builders + a batched-request demo.

`make_prefill_step` / `make_decode_step` are the pjit-able pure steps the
dry-run lowers at production shapes; `main` runs an actual small-model
serving session on CPU: export ternary weights (TWD packing), prefill a
batch of prompts through the LPSA streaming dataflow, then generate tokens
greedily from the ring caches.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch bitnet-1.3b --reduced \
      --prompt-len 64 --gen 32 --batch 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduced_cfg
from repro.models import model as MD
from repro.models.transformer import Runtime

__all__ = ["make_prefill_step", "make_decode_step", "main"]


def make_prefill_step(cfg, rt: Runtime, *, max_len: int):
    def prefill_step(sparams, inputs):
        return MD.prefill(sparams, cfg, inputs, rt, max_len=max_len)
    return prefill_step


def make_decode_step(cfg, rt: Runtime):
    def decode_step(sparams, caches, token, t):
        return MD.decode_step(sparams, cfg, caches, token, t, rt)
    return decode_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bitnet-1.3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--no-sparse", action="store_true",
                    help="full attention + full KV cache (naive baseline)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_cfg(cfg)
    rt = Runtime(serve_sparse=not args.no_sparse)
    max_len = args.prompt_len + args.gen

    params = MD.init_params(jax.random.PRNGKey(args.seed), cfg)
    sparams = MD.export_serving(params, cfg)
    nbytes = sum(x.nbytes for x in jax.tree.leaves(sparams))
    mbytes = sum(x.nbytes for x in jax.tree.leaves(params))
    print(f"[serve] {cfg.name}: serving weights {nbytes/1e6:.1f} MB "
          f"(master {mbytes/1e6:.1f} MB, {mbytes/max(nbytes,1):.1f}x TWD+quant)")

    prefill = jax.jit(make_prefill_step(cfg, rt, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg, rt))

    rng = np.random.default_rng(args.seed)
    if MD.uses_embeds(cfg):
        prompts = jnp.asarray(rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)), jnp.float32)
    else:
        prompts = jnp.asarray(rng.integers(
            0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    t0 = time.perf_counter()
    logits, caches = prefill(sparams, prompts)
    logits.block_until_ready()
    t_pre = time.perf_counter() - t0
    print(f"[serve] prefill {args.batch}x{args.prompt_len}: {t_pre*1e3:.1f} ms")

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        t = jnp.array(args.prompt_len + i)
        if MD.uses_embeds(cfg):
            step_in = jnp.take(sparams["embed"], tok, axis=0)[:, None, :].astype(jnp.float32)[:, 0]
            step_in = step_in[:, None, :]
        else:
            step_in = tok
        logits, caches = decode(sparams, caches, step_in, t)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_dec = time.perf_counter() - t0
    toks = jnp.stack(out, axis=1)
    print(f"[serve] decode {args.gen-1} steps: {t_dec*1e3:.1f} ms "
          f"({(args.gen-1)*args.batch/max(t_dec,1e-9):.1f} tok/s)")
    print(f"[serve] sample output ids: {np.asarray(toks[0])[:16].tolist()}")
    return toks


if __name__ == "__main__":
    main()
