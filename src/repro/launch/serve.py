"""Serving driver: pjit-able step builders + a CLI over repro.serve.

`make_prefill_step` / `make_decode_step` are the pjit-able pure steps the
dry-run lowers at production shapes.  `main` is now a thin CLI over
`repro.serve.ServeEngine`: export ternary weights (TWD packing), submit a
staggered trace of generation requests, and let the continuous-batching
engine prefill/decode them through per-sequence KV state.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch bitnet-1.3b --reduced \
      --prompt-len 64 --gen 32 --requests 4 --stagger 4 --temperature 0.8

``--serve-http`` flips the CLI from trace-replay into the always-on front
door: an asyncio HTTP server (repro.serve.server) over the same engine,
with an OpenAI-style streaming completions endpoint, 429 backpressure,
``/metrics`` live telemetry and SIGINT/SIGTERM-clean shutdown.
``--metrics-out`` writes the JSON-lines telemetry log in either mode.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduced_cfg
from repro.distributed.plan import Topology
from repro.kernels.ops import KernelMode
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.serve import Request, ServeConfig, ServeEngine

# CLI defaults come straight from the ServeConfig field defaults, so the
# two can never drift (satellite of the Topology/ShardingPlan redesign)
_D = {f.name: f.default for f in dataclasses.fields(ServeConfig)}

__all__ = ["make_prefill_step", "make_decode_step", "build_engine", "main"]


def make_prefill_step(cfg, rt: Runtime, *, max_len: int):
    def prefill_step(sparams, inputs):
        return MD.prefill(sparams, cfg, inputs, rt, max_len=max_len)
    return prefill_step


def make_decode_step(cfg, rt: Runtime):
    def decode_step(sparams, caches, token, t):
        return MD.decode_step(sparams, cfg, caches, token, t, rt)
    return decode_step


def build_engine(cfg, rt: Runtime, config: ServeConfig | None = None,
                 **legacy) -> ServeEngine:
    """Init params, export TWD serving weights, wrap them in a ServeEngine.

    Pass ``config=ServeConfig(...)``; loose kwargs (max_slots, max_len, ...)
    are forwarded through the engine's deprecated back-compat shim."""
    seed = config.seed if config is not None else legacy.get("seed", 0)
    params = MD.init_params(jax.random.PRNGKey(seed), cfg)
    sparams = MD.export_serving(params, cfg)
    nbytes = sum(x.nbytes for x in jax.tree.leaves(sparams))
    mbytes = sum(x.nbytes for x in jax.tree.leaves(params))
    print(f"[serve] {cfg.name}: serving weights {nbytes/1e6:.1f} MB "
          f"(master {mbytes/1e6:.1f} MB, {mbytes/max(nbytes,1):.1f}x TWD+quant)")
    return ServeEngine(cfg, sparams, rt, config=config, **legacy)


def _make_prompt(cfg, rng, length: int):
    if MD.uses_embeds(cfg):
        return jnp.asarray(rng.standard_normal((length, cfg.d_model)),
                           jnp.float32)
    return np.asarray(rng.integers(0, cfg.vocab, (length,)), np.int32)


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="TENET serving CLI: trace replay or HTTP front door "
                    "over repro.serve.ServeEngine")

    eng = ap.add_argument_group(
        "engine", "model + ServeEngine knobs (defaults mirror ServeConfig)")
    eng.add_argument("--arch", default="bitnet-1.3b")
    eng.add_argument("--reduced", action="store_true")
    eng.add_argument("--slots", type=int, default=_D["max_slots"])
    eng.add_argument("--top-k", type=int, default=_D["top_k"])
    eng.add_argument("--no-sparse", action="store_true",
                     help="full attention + full KV cache (naive baseline)")
    eng.add_argument("--layout", choices=["auto", "paged"],
                     default=_D["layout"],
                     help="KV layout: 'auto' keeps per-slot caches; 'paged' "
                          "shares one refcounted page arena per full-attn "
                          "layer with lazy allocation + radix prefix sharing")
    eng.add_argument("--page-size", type=int, default=_D["page_size"],
                     help="tokens per KV page (paged layout)")
    eng.add_argument("--num-pages", type=int, default=_D["num_pages"],
                     help="pool capacity incl. the null page; 0 auto-sizes "
                          "to the per-slot worst case")
    eng.add_argument("--no-prefix-sharing", action="store_true",
                     help="disable the radix-trie prompt-prefix index "
                          "(paged layout)")
    eng.add_argument("--kernel-mode", default="ref",
                     type=lambda s: KernelMode.parse(s).value,
                     help="ternary-linear execution path (kernels/ops."
                          "KERNEL_MODES); kernel modes route slab-aligned "
                          "packed+DAS layers through the fused "
                          "das_ternary_gemm datapath; 'tuned' autotunes "
                          "per-shape at engine construction and caches "
                          "winners on disk; 'sharded' is the GSPMD-safe "
                          "path a --tp/--dp mesh forces")
    eng.add_argument("--moe-expert-capacity", type=int,
                     default=_D["moe_expert_capacity"],
                     help="bound the per-expert token load per decode tick "
                          "by deferring admissions (MoE configs only; 0 = "
                          "unbounded — decode itself never drops tokens)")
    eng.add_argument("--seed", type=int, default=_D["seed"])

    tr = ap.add_argument_group("trace replay", "synthetic request trace")
    tr.add_argument("--requests", type=int, default=4)
    tr.add_argument("--prompt-len", type=int, default=64)
    tr.add_argument("--gen", type=int, default=32)
    tr.add_argument("--stagger", type=int, default=0,
                    help="virtual decode steps between request arrivals")
    tr.add_argument("--temperature", type=float, default=0.0)
    tr.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="append JSON-lines telemetry (one line per "
                         "finished request + periodic tick snapshots) to "
                         "PATH")

    sched = ap.add_argument_group("scheduler", "admission order + SLOs")
    sched.add_argument("--policy", choices=["continuous", "wave"],
                       default=_D["policy"])
    sched.add_argument("--scheduler", choices=["fifo", "deadline"],
                       default=None,
                       help="admission order: 'fifo' (aged priority-then-"
                            "arrival) or 'deadline' (earliest-effective-"
                            "deadline-first over Request.slo_steps); "
                            "defaults to 'deadline' under --serve-http, "
                            "else 'fifo'")
    sched.add_argument("--slo-steps", type=int, default=0,
                       help="per-request deadline budget in virtual decode "
                            "steps (0 = no SLO); attached to every trace "
                            "request and used as the server's default for "
                            "requests that don't carry slo_steps")
    sched.add_argument("--preemption", action="store_true",
                       help="deadline scheduler only: truncate-and-retire "
                            "the youngest over-SLO-budget slot when the "
                            "queue head would otherwise miss its deadline")

    http = ap.add_argument_group("HTTP front door", "--serve-http mode")
    http.add_argument("--serve-http", action="store_true",
                      help="run the always-on HTTP front door instead of a "
                           "trace replay (POST /v1/completions with "
                           "stream=true, GET /metrics, GET /healthz; "
                           "SIGINT/SIGTERM shut down cleanly)")
    http.add_argument("--host", default="127.0.0.1")
    http.add_argument("--port", type=int, default=8080,
                      help="listen port for --serve-http (0 = ephemeral)")
    http.add_argument("--max-queue-depth", type=int, default=64,
                      help="queued requests beyond which the server answers "
                           "429 (backpressure)")

    dist = ap.add_argument_group(
        "distributed", "SPMD serving over a (dp, tp) mesh + elastic "
        "recovery (run under XLA_FLAGS="
        "--xla_force_host_platform_device_count=N to emulate N devices)")
    dist.add_argument("--tp", type=int, default=None, metavar="N",
                      help="tensor-parallel ways: shard the packed weight "
                           "slabs Megatron column/row style over the "
                           "'model' mesh axis")
    dist.add_argument("--dp", type=int, default=None, metavar="N",
                      help="data-parallel ways: shard the slot batch over "
                           "the 'data' mesh axis")
    dist.add_argument("--print-plan", action="store_true",
                      help="print the resolved ShardingPlan (per-leaf "
                           "PartitionSpecs) and the cache specs")
    dist.add_argument("--inject-failure", type=int, action="append",
                      default=None, metavar="STEP",
                      help="inject a WorkerFailure before decode step STEP "
                           "(repeatable): exercises snapshot -> mesh "
                           "shrink -> reshard -> replay recovery")
    dist.add_argument("--inject-lost", type=int, default=1, metavar="N",
                      help="devices lost per injected failure (default 1)")
    return ap


def _check_topology(ap, cfg, args) -> Topology | None:
    """Resolve --tp/--dp into a Topology, rejecting shapes the config's
    head/FFN dims can't divide with a clear argparse error."""
    if args.tp is None and args.dp is None:
        return None     # single-device; --inject-failure still works
                        # (in-place recovery: snapshot + rebuild + replay)
    tp = args.tp or 1
    dp = args.dp or 1
    if tp < 1 or dp < 1:
        ap.error("--tp/--dp must be >= 1")
    if tp > 1:
        bad = [f"{name}={dim}" for name, dim in (
            ("n_heads", cfg.n_heads), ("n_kv_heads", cfg.n_kv_heads),
            ("d_ff", cfg.d_ff)) if dim % tp]
        if cfg.moe is not None and cfg.moe.n_experts % tp:
            bad.append(f"moe.n_experts={cfg.moe.n_experts}")
        if bad:
            ap.error(f"--tp {tp} does not divide {args.arch}'s "
                     f"{', '.join(bad)}; pick a tp that divides the "
                     f"head/FFN dims (try --reduced, or a smaller --tp)")
    topo = Topology(dp=dp, tp=tp)
    n_dev = len(jax.devices())
    if topo.n_devices > n_dev:
        ap.error(f"topology (dp={dp}, tp={tp}) needs {topo.n_devices} "
                 f"devices but jax sees {n_dev}; relaunch with XLA_FLAGS="
                 f"--xla_force_host_platform_device_count={topo.n_devices} "
                 f"(set before jax initializes)")
    return topo


def main(argv=None):
    ap = _build_parser()
    args = ap.parse_args(argv)
    if args.scheduler is None:
        args.scheduler = "deadline" if args.serve_http else "fifo"
    if args.preemption and args.scheduler != "deadline":
        ap.error("--preemption requires --scheduler deadline")

    # gate bad configs here with argparse-style errors instead of letting
    # them traceback deep inside cache/engine init
    try:
        cfg = get_config(args.arch)
    except KeyError as e:
        ap.error(str(e.args[0] if e.args else e))
    if args.reduced:
        cfg = reduced_cfg(cfg)
    topology = _check_topology(ap, cfg, args)
    rt = Runtime(serve_sparse=not args.no_sparse,
                 kernel_mode=args.kernel_mode)
    max_len = args.prompt_len + args.gen
    if args.layout == "paged" and max_len % args.page_size:
        max_len += args.page_size - max_len % args.page_size

    try:
        sc = ServeConfig(max_slots=args.slots, max_len=max_len,
                         layout=args.layout, page_size=args.page_size,
                         num_pages=args.num_pages,
                         prefix_sharing=not args.no_prefix_sharing,
                         top_k=args.top_k, seed=args.seed,
                         policy=args.policy,
                         moe_expert_capacity=args.moe_expert_capacity,
                         scheduler=args.scheduler,
                         preemption=args.preemption,
                         topology=topology)
        eng = build_engine(cfg, rt, config=sc)
    except ValueError as e:
        ap.error(f"config not serveable: {e}")
    if args.inject_failure:
        from repro.distributed import fault
        eng.fault_injector = fault.FaultInjector(
            fail_at=tuple(sorted(set(args.inject_failure))))
        eng.fault_lost_devices = args.inject_lost
    if topology is not None:
        print(f"[serve] topology: dp={topology.dp} tp={topology.tp} "
              f"({topology.n_devices} devices, mesh axes "
              f"{topology.axis_names})")
    if args.print_plan and eng.plan is not None:
        print(eng.plan.describe(eng.sparams))

    # the resolved slot-state union (one entry per distinct layout, in
    # stack order) — the README's "serving the model zoo" table, live
    layouts: dict[str, int] = {}
    for row in eng.layout_summary():
        layouts[row["layout"]] = layouts.get(row["layout"], 0) + 1
    print("[serve] slot-state layouts: "
          + ", ".join(f"{k} x{v}" for k, v in layouts.items()))

    from repro.serve.metrics import Telemetry
    tele = Telemetry(engine=eng, jsonl_path=args.metrics_out)

    if args.serve_http:
        return _serve_http(args, eng, tele)

    rng = np.random.default_rng(args.seed)
    slo = args.slo_steps if args.slo_steps > 0 else None
    for i in range(args.requests):
        eng.submit(Request(uid=i, prompt=_make_prompt(cfg, rng, args.prompt_len),
                           max_new_tokens=args.gen,
                           temperature=args.temperature,
                           arrival=i * args.stagger, slo_steps=slo))
    results = eng.run()

    st = eng.stats
    print(f"[serve] {st.decode_steps} decode steps, slot utilization "
          f"{st.slot_utilization:.2f}, {st.generated_tokens} tokens in "
          f"{st.wall_seconds:.2f}s "
          f"({st.generated_tokens/max(st.wall_seconds,1e-9):.1f} tok/s)")
    if args.layout == "paged":
        pool = eng.pool_stats()
        if pool["num_pages"]:
            print(f"[serve] paged pool: {pool['pages_peak']}/"
                  f"{pool['num_pages']} pages peak "
                  f"({pool['bytes_peak']/1e6:.2f} MB vs dense "
                  f"{pool['dense_equiv_bytes']/1e6:.2f} MB), "
                  f"{st.prefix_hits} prefix hits "
                  f"({st.prompt_tokens_reused} tokens reused), "
                  f"{st.cow_copies} CoW copies")
        else:
            print("[serve] paged pool: no full-attention layers under this "
                  "config (LPSA/ring only) -> no page arenas; pass "
                  "--no-sparse to page the global layers")
    for uid in sorted(results):
        r = results[uid]
        slo_note = "" if r.slo_steps is None else \
            f", slo {'MET' if r.slo_met else 'MISS'} ({r.slo_steps})"
        print(f"[serve] req {uid}: ttft {r.ttft_steps} steps, latency "
              f"{r.latency_steps} steps{slo_note}, "
              f"ids {r.tokens[:8].tolist()}...")
    if st.reshards:
        t = eng.topology
        topo_note = "" if t is None else f", topology dp={t.dp} tp={t.tp}"
        if len(results) == args.requests:
            print(f"[serve] recovery clean: all {len(results)} in-flight "
                  f"requests completed (reshards={st.reshards}, recovery "
                  f"{st.recovery_seconds:.2f}s{topo_note})")
        else:
            print(f"[serve] recovery INCOMPLETE: {len(results)}/"
                  f"{args.requests} requests completed after "
                  f"{st.reshards} reshard(s){topo_note}")
    if args.slo_steps > 0:
        tracked = [r for r in results.values() if r.slo_steps is not None]
        met = sum(r.slo_met for r in tracked)
        print(f"[serve] SLO attainment: {met}/{len(tracked)} "
              f"({met/max(len(tracked), 1):.0%}) at {args.slo_steps} steps, "
              f"{eng.stats.preemptions} preemptions")
    if args.metrics_out:
        tele.close()
        print(f"[serve] telemetry JSONL -> {args.metrics_out}")
    return results


def _serve_http(args, eng, tele):
    """The always-on front door: run until SIGINT/SIGTERM, shut down
    cleanly (joins the engine thread, closes the telemetry log)."""
    import asyncio
    import contextlib
    import signal

    from repro.serve.server import ServeHTTPServer

    default_slo = args.slo_steps if args.slo_steps > 0 else None
    srv = ServeHTTPServer(eng, args.host, args.port,
                          max_queue_depth=args.max_queue_depth,
                          default_slo_steps=default_slo, telemetry=tele)

    async def _amain():
        stop = asyncio.Event()
        loop = asyncio.get_running_loop()
        for sig in (signal.SIGINT, signal.SIGTERM):
            with contextlib.suppress(NotImplementedError):
                loop.add_signal_handler(sig, stop.set)
        await srv.start()
        print(f"[serve] http front door on http://{srv.host}:{srv.port} "
              f"(scheduler={args.scheduler}, "
              f"default_slo={default_slo}, "
              f"max_queue_depth={args.max_queue_depth}); "
              f"POST /v1/completions, GET /metrics", flush=True)
        await stop.wait()
        print("[serve] shutting down...", flush=True)
        await srv.stop()
        st = eng.stats
        print(f"[serve] clean shutdown: {st.decode_steps} decode steps, "
              f"{st.generated_tokens} tokens, "
              f"{tele.requests_finished} requests served", flush=True)

    asyncio.run(_amain())
    return None


if __name__ == "__main__":
    main()
