"""Training driver: step builders (shared with dryrun) + a runnable main.

`make_train_step` returns the pjit-able pure step; `main` runs an actual
CPU-scale training job (reduced config, synthetic data) with checkpointing,
fault-tolerant restart and straggler monitoring — the same loop a pod-scale
launch would run, minus the accelerators.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch bitnet-1.3b --steps 50 \
      --reduced --batch 8 --seq 128 [--inject-failure 17] [--ckpt-dir /tmp/ck]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as reduced_cfg
from repro.data.pipeline import SyntheticLM
from repro.distributed import fault
from repro.distributed.plan import ShardingPlan, Topology
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.optim import adamw, schedule
from repro import checkpoint as ckpt_lib

__all__ = ["make_runtime", "make_train_step", "train_shardings", "main"]


def make_runtime(mesh, cfg, global_batch: int, *, kernel_mode="ref",
                 serve_sparse=True) -> Runtime:
    if mesh is None:
        return Runtime(kernel_mode=kernel_mode, serve_sparse=serve_sparse)
    from repro.launch.mesh import dp_axes_for
    return Runtime(mesh=mesh, dp_axes=dp_axes_for(mesh, global_batch),
                   ep_axis="model", kernel_mode=kernel_mode,
                   serve_sparse=serve_sparse)


def make_train_step(cfg, rt: Runtime, *, peak_lr=3e-4, warmup=100,
                    total=10_000, sched="cosine", weight_decay=0.1):
    sched_fn = (schedule.wsd_schedule if sched == "wsd"
                else schedule.cosine_schedule)

    def train_step(params, opt: adamw.AdamWState, batch):
        def lf(p):
            return MD.loss_fn(p, cfg, batch, rt)
        (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(params)
        lr = sched_fn(opt.step, peak_lr=peak_lr, warmup=warmup, total=total)
        params, opt, info = adamw.adamw_step(params, grads, opt, lr=lr,
                                             weight_decay=weight_decay)
        return params, opt, {"loss": loss, "lr": lr, **info}

    return train_step


def train_shardings(mesh, params_shape, opt_shape, *, multi_pod: bool):
    """NamedShardings for (params, opt, batch) of a train step."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    plan = ShardingPlan.for_tree(params_shape, Topology.from_mesh(mesh),
                                 validate=False)
    ospecs = adamw.AdamWState(step=P(),
                              m=plan.zero1(opt_shape.m),
                              v=plan.zero1(opt_shape.v))
    bspec = {"inputs": plan.batch, "labels": plan.batch}
    ns = lambda tree: jax.tree.map(  # noqa: E731
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    return ns(plan.params), ns(ospecs), ns(bspec)


# ---------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bitnet-1.3b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--sched", choices=("cosine", "wsd"), default="cosine")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--inject-failure", type=int, action="append", default=[])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_cfg(cfg)
    # minicpm trains with WSD per its paper
    sched = "wsd" if (args.arch.startswith("minicpm") and args.sched == "cosine") \
        else args.sched
    rt = Runtime()
    step_fn = jax.jit(make_train_step(cfg, rt, peak_lr=args.lr, warmup=10,
                                      total=args.steps, sched=sched))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch,
                       seed=args.seed)
    params = MD.init_params(jax.random.PRNGKey(args.seed), cfg)
    opt = adamw.adamw_init(params)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    monitor = fault.StragglerMonitor()
    injector = fault.FaultInjector(tuple(args.inject_failure))
    losses: list[float] = []

    def one_step(state, step):
        params, opt = state
        batch = jax.tree.map(jnp.asarray, data.batch_at(step))
        params, opt, m = step_fn(params, opt, batch)
        loss = float(m["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"  step {step:5d} loss {loss:.4f} lr {float(m['lr']):.2e} "
                  f"gnorm {float(m['grad_norm']):.3f}")
        return params, opt

    if args.ckpt_dir:
        save = lambda st, s: ckpt_lib.save_checkpoint(  # noqa: E731
            args.ckpt_dir, s, {"params": st[0], "opt": st[1]})
        def restore():
            tree, s = ckpt_lib.restore_checkpoint(args.ckpt_dir)
            print(f"  [fault] restored step {s}")
            return (tree["params"], tree["opt"]), s
        state, stats = fault.resilient_loop(
            init_state=(params, opt), step_fn=one_step, n_steps=args.steps,
            save_fn=save, restore_fn=restore, ckpt_every=args.ckpt_every,
            injector=injector, monitor=monitor)
        print(f"[train] done. restarts={stats['restarts']} "
              f"stragglers={len(stats['stragglers'])}")
    else:
        state = (params, opt)
        for s in range(args.steps):
            state = one_step(state, s)
    print(f"[train] final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
