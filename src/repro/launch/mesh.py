"""Production mesh construction.

Axes: ("data", "model") single pod (16x16 = 256 chips), ("pod", "data",
"model") across 2 pods (512 chips).  A FUNCTION, not a module constant, so
importing this module never touches jax device state (smoke tests must see
1 device; only launch/dryrun.py forces 512 host devices).
"""

from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "mesh_axes", "dp_axes_for"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — run under "
            f"launch/dryrun.py (XLA_FLAGS=--xla_force_host_platform_device_count=512)")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def mesh_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def dp_axes_for(mesh, global_batch: int) -> tuple[str, ...]:
    """Data-parallel axes usable for this batch (batch 1 => replicate)."""
    cand = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = 1
    out = []
    for a in cand:
        if global_batch % (dp * mesh.shape[a]) == 0:
            out.append(a)
            dp *= mesh.shape[a]
    return tuple(out)
