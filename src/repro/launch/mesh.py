"""Production mesh construction — thin delegates over ``Topology``.

Axes: ("data", "model") single pod (16x16 = 256 chips), ("pod", "data",
"model") across 2 pods (512 chips).  FUNCTIONS, not module constants, so
importing this module never touches jax device state (smoke tests must see
1 device; only launch/dryrun.py forces 512 host devices).

The mesh geometry itself now lives in ``distributed.plan.Topology``
(``Topology.production().build_mesh()``); these wrappers keep the old call
sites working and stay the place launch scripts import from.
"""

from __future__ import annotations

from repro.distributed.plan import Topology

__all__ = ["make_production_mesh", "mesh_axes", "dp_axes_for"]


def make_production_mesh(*, multi_pod: bool = False):
    return Topology.production(multi_pod=multi_pod).build_mesh()


def mesh_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def dp_axes_for(mesh, global_batch: int) -> tuple[str, ...]:
    """Data-parallel axes usable for this batch (batch 1 => replicate)."""
    return Topology.from_mesh(mesh).dp_axes_for(global_batch)
