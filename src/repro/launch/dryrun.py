import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the full-size config and the production mesh (single-pod 16x16,
     multi-pod 2x16x16 — 512 virtual host devices, set above BEFORE any
     other import so jax picks it up at first init),
  2. lowers the right step (train_step / prefill_step / decode_step) from
     ShapeDtypeStruct stand-ins (no allocation) with the production
     in/out shardings,
  3. compiles, prints memory_analysis() and cost_analysis(),
  4. extracts the three roofline terms (compute / memory / collective) from
     the compiled HLO: FLOPs + bytes from cost_analysis, collective bytes by
     parsing the post-SPMD HLO for all-gather / all-reduce / reduce-scatter /
     all-to-all / collective-permute operands,
  5. appends a JSON record consumed by EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out dryrun.json
"""

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import math         # noqa: E402
import re           # noqa: E402
import time         # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402
from jax.tree_util import DictKey  # noqa: E402

from repro.configs import ARCH_MODULES, get_config  # noqa: E402
from repro.configs.shapes import SHAPES, shape_by_name  # noqa: E402
from repro.distributed.plan import ShardingPlan, Topology  # noqa: E402
from repro.launch.mesh import make_production_mesh, dp_axes_for  # noqa: E402
from repro.launch.serve import make_decode_step, make_prefill_step  # noqa: E402
from repro.launch.train import make_runtime, make_train_step, train_shardings  # noqa: E402
from repro.models import model as MD  # noqa: E402
from repro.optim import adamw  # noqa: E402

# ---------------------------------------------------------------------------
# TPU v5e-class roofline constants (DESIGN.md §2)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link

_DT_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
             "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2,
             "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\w+\[[0-9,]*\]\S*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DT_BYTES.get(dtype, 4)


def collective_bytes(hlo: str) -> dict:
    """Per-device wire-byte estimate by collective kind (post-SPMD HLO)."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[-1][:40]:
            continue
        kind = m.group(1)
        shapes = _SHAPE_RE.findall(line.split("=", 1)[1])
        if not shapes:
            continue
        out_bytes = _shape_bytes(*shapes[0])
        opnd = sum(_shape_bytes(d, s) for d, s in shapes[1:]) or out_bytes
        if kind == "all-reduce":
            out[kind] += 2 * out_bytes
        elif kind == "reduce-scatter":
            out[kind] += opnd
        else:
            out[kind] += out_bytes
    return out


def active_param_count(cfg) -> int:
    """Parameters touched per token (MoE: routed top_k + shared only)."""
    if cfg.moe is None:
        return cfg.param_count()
    e = cfg.moe
    total = cfg.param_count()
    all_experts = cfg.n_layers * e.n_experts * 3 * cfg.d_model * e.d_expert
    active = cfg.n_layers * (e.top_k + e.n_shared) * 3 * cfg.d_model * e.d_expert
    return total - all_experts + active


def model_flops(cfg, shape) -> float:
    """6*N*D for training, 2*N*D for serving (active params for MoE)."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch  # decode: per one new token


# ---------------------------------------------------------------------------
# cache sharding specs
# ---------------------------------------------------------------------------

def _cache_leaf_spec(path, leaf, cfg, dp):
    names = [str(k.key) for k in path if isinstance(k, DictKey)]
    stacked = "stacked" in names
    name = names[-1] if names else ""
    nd = leaf.ndim - (1 if stacked else 0)
    off = 1 if stacked else 0
    dims = leaf.shape[off:]

    def axis_div(i):  # sharding requires exact divisibility by model=16
        return dims[i] % 16 == 0

    spec: list = [None] * nd
    if name in ("k", "v") and nd == 4:
        spec[0] = dp or None
        for cand in (2, 3):     # prefer kv-heads, fall back to head_dim
            if axis_div(cand):
                spec[cand] = "model"
                break
    elif name == "conv" and nd == 3:
        spec = [dp or None, None, "model" if axis_div(2) else None]
    elif name in ("ssm", "wkv", "s") and nd == 4:
        spec[0] = dp or None
        for cand in (1, 2, 3):
            if axis_div(cand):
                spec[cand] = "model"
                break
    elif name in ("shift_t", "shift_c") and nd == 3:
        spec = [dp or None, None, None]
    elif name == "pos":
        spec = [None] * nd
    if stacked:
        spec = [None] + spec
    return P(*spec)


def cache_specs(cfg, caches_shape, dp):
    return jax.tree_util.tree_map_with_path(
        lambda p, x: _cache_leaf_spec(p, x, cfg, dp), caches_shape)


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh, *, variant: str = "paper",
               override_layers: int | None = None):
    """-> (lower_fn, meta) — lower_fn() returns the jax `Lowered`."""
    cfg = get_config(arch)
    if override_layers is not None:
        cfg = dataclasses.replace(cfg, n_layers=override_layers,
                                  scan_layers=False)
    shape = shape_by_name(shape_name)
    multi_pod = "pod" in mesh.axis_names
    topo = Topology.from_mesh(mesh)
    dp = dp_axes_for(mesh, shape.global_batch)

    # `variant` is a comma-joined token set (hillclimb knobs):
    #   paper     — TWD packed + DAS + LPSA (decode_32k keeps full cache)
    #   baseline  — naive: int8-resident weights, full attention, no DAS
    #   lpsa      — ring cache on decode_32k too
    #   int8w/bf16w — serve weight format (isolates the TWD term)
    #   nodas     — disable DAS
    #   noremat   — activation checkpointing off (train)
    #   dp        — replicate params, batch over (data, model): TP -> pure DP
    tokens = set(variant.split(","))
    if "baseline" in tokens:
        cfg = dataclasses.replace(
            cfg, ternary=dataclasses.replace(cfg.ternary, das=None,
                                             serve_format="int8"),
            lpsa=None)
    if "int8w" in tokens:
        cfg = dataclasses.replace(cfg, ternary=dataclasses.replace(
            cfg.ternary, serve_format="int8"))
    if "bf16w" in tokens:
        cfg = dataclasses.replace(cfg, ternary=dataclasses.replace(
            cfg.ternary, serve_format="bf16"))
    if "nodas" in tokens:
        cfg = dataclasses.replace(cfg, ternary=dataclasses.replace(
            cfg.ternary, das=None))
    if "noremat" in tokens:
        cfg = dataclasses.replace(cfg, remat=False)
    serve_sparse = not (shape.name == "decode_32k" and "lpsa" not in tokens)
    if "lpsa" in tokens or shape.name == "long_500k":
        serve_sparse = True

    rt = make_runtime(mesh, cfg, shape.global_batch, serve_sparse=serve_sparse)
    b, s = shape.global_batch, shape.seq_len
    tokens_dtype = jnp.int32
    embeds = MD.uses_embeds(cfg)

    def in_shape():
        if embeds:
            return jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                        jnp.dtype(cfg.dtype))
        return jax.ShapeDtypeStruct((b, s), tokens_dtype)

    params_shape = jax.eval_shape(
        lambda: MD.init_params(jax.random.PRNGKey(0), cfg))

    ns = lambda spec_tree: jax.tree.map(  # noqa: E731
        lambda sp: NamedSharding(mesh, sp), spec_tree,
        is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        opt_shape = jax.eval_shape(lambda p: adamw.adamw_init(p), params_shape)
        p_sh, o_sh, b_sh = train_shardings(mesh, params_shape, opt_shape,
                                           multi_pod=multi_pod)
        if "dpattn" in tokens:  # MoE: EP stays on model, rest replicated,
            # batch over data only (attention compute replicated x16 --
            # cheap next to experts; kills the TP activation all-reduces)
            def _dpattn(spec_tree, shapes):
                def one(path, sp, shp):
                    names = [str(k.key) for k in path
                             if hasattr(k, "key")]
                    if any(n.startswith("experts_") for n in names):
                        return NamedSharding(mesh, sp)
                    return NamedSharding(mesh, P())
                return jax.tree_util.tree_map_with_path(
                    one, spec_tree, shapes,
                    is_leaf=lambda x: isinstance(x, P))
            pplan = ShardingPlan.for_tree(params_shape, topo, validate=False)
            p_sh = _dpattn(pplan.params, params_shape)
            z1 = pplan.zero1(opt_shape.m)
            o_sh = adamw.AdamWState(
                step=NamedSharding(mesh, P()),
                m=_dpattn(z1, opt_shape.m), v=_dpattn(z1, opt_shape.v))
            b_sh = {"inputs": NamedSharding(mesh, P(("data",))),
                    "labels": NamedSharding(mesh, P(("data",)))}
        if "dp" in tokens:   # pure DP + ZeRO: params replicated, batch wide
            dp_all = tuple(mesh.axis_names)
            pplan = ShardingPlan.for_tree(params_shape, topo, validate=False)
            repl = jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                pplan.params,
                                is_leaf=lambda x: isinstance(x, P))
            # moments: start from replicated (the TP specs may hit dims the
            # model axis doesn't divide, e.g. bitnet's d_ff=5460), then ZeRO
            # over data and model wherever divisible
            z0 = jax.tree.map(lambda _: P(), pplan.params,
                              is_leaf=lambda x: isinstance(x, P))
            z1 = pplan.zero1(opt_shape.m, base=z0)
            z2 = pplan.zero1(opt_shape.m, data_axis="model", base=z1)
            o_sh = adamw.AdamWState(
                step=NamedSharding(mesh, P()),
                m=jax.tree.map(lambda sp: NamedSharding(mesh, sp), z2,
                               is_leaf=lambda x: isinstance(x, P)),
                v=jax.tree.map(lambda sp: NamedSharding(mesh, sp), z2,
                               is_leaf=lambda x: isinstance(x, P)))
            p_sh = repl
            b_sh = {"inputs": NamedSharding(mesh, P(dp_all)),
                    "labels": NamedSharding(mesh, P(dp_all))}
        step = make_train_step(cfg, rt)
        batch_shape = {"inputs": in_shape(),
                       "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if embeds:
            b_sh = {"inputs": NamedSharding(mesh, P(dp, None, None)),
                    "labels": NamedSharding(mesh, P(dp))}

        def lower():
            return jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                           out_shardings=(p_sh, o_sh, None)).lower(
                params_shape, opt_shape, batch_shape)
        n_state_bytes = sum(
            x.size * x.dtype.itemsize
            for x in jax.tree.leaves((params_shape, opt_shape)))
        return lower, dict(cfg=cfg, shape=shape, rt=rt,
                           state_bytes=n_state_bytes)

    sparams_shape = jax.eval_shape(
        lambda: MD.export_serving(MD.init_params(jax.random.PRNGKey(0), cfg),
                                  cfg))
    sp_plan = ShardingPlan.for_tree(sparams_shape, topo, validate=False)
    sp_sh = ns(sp_plan.params)
    state_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(sparams_shape))

    if shape.kind == "prefill":
        if "dp" in tokens:  # replicate serving weights, batch on data only.
            # NOTE: analytically worse for prefill at batch<devices — the
            # model axis idles (x16 redundant compute) and the batch cannot
            # span 256 ways; kept for completeness (see EXPERIMENTS §Perf).
            sp_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                 sp_plan.params,
                                 is_leaf=lambda x: isinstance(x, P))
            dp = ("data",)
        step = make_prefill_step(cfg, rt, max_len=s + 1)
        in_sh = NamedSharding(mesh, P(dp, None, None) if embeds else P(dp))

        def lower():
            return jax.jit(step, in_shardings=(sp_sh, in_sh)).lower(
                sparams_shape, in_shape())
        return lower, dict(cfg=cfg, shape=shape, rt=rt,
                           state_bytes=state_bytes)

    # decode: one token against a seq_len-deep cache/state
    caches_shape = jax.eval_shape(
        lambda: MD.init_caches(None, cfg, b, s, rt, jnp.dtype(cfg.dtype)))
    c_sh = ns(cache_specs(cfg, caches_shape, dp))
    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(caches_shape))
    step = make_decode_step(cfg, rt)
    if embeds:
        tok_shape = jax.ShapeDtypeStruct((b, 1, cfg.d_model),
                                         jnp.dtype(cfg.dtype))
        tok_sh = NamedSharding(mesh, P(dp, None, None))
    else:
        tok_shape = jax.ShapeDtypeStruct((b,), jnp.int32)
        tok_sh = NamedSharding(mesh, P(dp))

    def lower():
        return jax.jit(step, in_shardings=(sp_sh, c_sh, tok_sh, None)).lower(
            sparams_shape, caches_shape, tok_shape,
            jax.ShapeDtypeStruct((), jnp.int32))
    return lower, dict(cfg=cfg, shape=shape, rt=rt,
                       state_bytes=state_bytes + cache_bytes)


# ---------------------------------------------------------------------------

def _cell_cost(arch, shape_name, mesh, variant, override_layers):
    """(flops, bytes, collective-bytes) of an unrolled `override_layers` model.

    XLA's cost_analysis visits scan bodies ONCE regardless of trip count, so
    per-group costs come from unrolled 1-group and 2-group compiles; the cell
    total is reconstructed linearly (run_cell)."""
    lower_fn, _ = build_cell(arch, shape_name, mesh, variant=variant,
                             override_layers=override_layers)
    with mesh:
        compiled = lower_fn().compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(sum(coll.values())), coll)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             variant: str = "paper", verbose: bool = True,
             scan_correction: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = math.prod(mesh.devices.shape)
    t0 = time.time()
    lower_fn, meta = build_cell(arch, shape_name, mesh, variant=variant)
    with mesh:
        lowered = lower_fn()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
    except Exception:  # CPU backend may not implement it
        mem = None
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    coll_total = float(sum(coll.values()))

    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    shape = meta["shape"]
    cfg = meta["cfg"]

    # ---- scan correction: reconstruct totals from unrolled 1/2-group costs
    plen = len(cfg.layer_pattern)
    n_groups, tail = divmod(cfg.n_layers, plen)
    corrected = False
    if scan_correction and cfg.scan_layers and n_groups >= 1 \
            and cfg.n_layers > plen:
        try:
            f1, b1, c1, _ = _cell_cost(arch, shape_name, mesh, variant, plen)
            f2, b2, c2, _ = _cell_cost(arch, shape_name, mesh, variant,
                                       2 * plen)
            mult = (n_groups - 1) + tail / plen
            flops = f1 + (f2 - f1) * mult
            bytes_acc = b1 + (b2 - b1) * mult
            coll_total = c1 + (c2 - c1) * mult
            corrected = True
        except Exception as e:  # noqa: BLE001
            print(f"  [warn] scan correction failed: {e!r}"[:200])

    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll_total / LINK_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    useful = mf / (flops * n_dev) if flops else 0.0

    rec = dict(
        arch=arch, shape=shape_name, mesh="2x16x16" if multi_pod else "16x16",
        variant=variant, devices=n_dev,
        flops_per_dev=flops, bytes_per_dev=bytes_acc,
        collective_bytes_per_dev=coll_total, collectives=coll,
        t_compute_s=t_compute, t_memory_s=t_memory, t_collective_s=t_coll,
        dominant=dominant, model_flops=mf,
        useful_flops_frac=useful, scan_corrected=corrected,
        raw_flops_per_dev=float(cost.get("flops", 0.0)),
        state_bytes_per_dev=meta["state_bytes"] / n_dev,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory_analysis=str(mem) if mem is not None else None,
    )
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']} ({variant}): "
              f"OK lower={t_lower:.0f}s compile={t_compile:.0f}s")
        print(f"  flops/dev={flops:.3e} bytes/dev={bytes_acc:.3e} "
              f"coll/dev={coll_total:.3e}")
        print(f"  roofline: compute={t_compute:.4f}s memory={t_memory:.4f}s "
              f"collective={t_coll:.4f}s -> {dominant}-bound")
        print(f"  state/dev={rec['state_bytes_per_dev']/2**30:.2f} GiB  "
              f"useful-flops={useful:.2%}")
        if mem is not None:
            print(f"  memory_analysis: {mem}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--variant", default="paper")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    archs = list(ARCH_MODULES)[:10] if (args.all or args.arch is None) \
        else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    records, failures = [], []
    done = set()
    if args.resume and args.out and os.path.exists(args.out):
        with open(args.out) as f:
            prev = json.load(f)
        records = prev.get("records", [])
        done = {(r["arch"], r["shape"], r["mesh"], r.get("variant", "paper"))
                for r in records}
        print(f"[dryrun] resuming: {len(done)} cells already done")
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                key = (arch, shape_name, "2x16x16" if mp else "16x16",
                       args.variant)
                if key in done:
                    continue
                try:
                    # §Roofline is single-pod only: multi-pod cells need
                    # compile-success + memory, not the 3x scan-correction.
                    records.append(run_cell(arch, shape_name, multi_pod=mp,
                                            variant=args.variant,
                                            scan_correction=not mp))
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape_name, mp, repr(e)[:500]))
                    print(f"[dryrun] FAIL {arch} x {shape_name} "
                          f"multi_pod={mp}: {e!r}"[:600])
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump({"records": records, "failures": failures},
                                  f, indent=1)
    print(f"[dryrun] {len(records)} cells OK, {len(failures)} failed")
    return 0 if not failures else 1


if __name__ == "__main__":
    raise SystemExit(main())
