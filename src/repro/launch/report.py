"""Render dryrun_results.json into the EXPERIMENTS.md §Dry-run / §Roofline
tables.

Usage:
  PYTHONPATH=src python -m repro.launch.report dryrun_results.json > tables.md
"""

from __future__ import annotations

import json
import sys


def _f(x: float) -> str:
    if x == 0:
        return "0"
    if x >= 1e4 or x < 1e-3:
        return f"{x:.2e}"
    return f"{x:.3f}"


def render(path: str) -> str:
    with open(path) as f:
        data = json.load(f)
    recs = data["records"]
    fails = data.get("failures", [])
    out = []

    out.append("### §Dry-run — lower+compile results, every (arch × shape × mesh)\n")
    out.append(f"**{len(recs)} cells compiled, {len(fails)} failures.** "
               "Single-pod mesh 16×16 (256 chips), multi-pod 2×16×16 (512). "
               "HLO flops/bytes are scan-corrected (unrolled 1- vs 2-group "
               "reconstruction); collective bytes parsed from post-SPMD HLO; "
               "state = actual per-device bytes under the production "
               "shardings (params + optimizer for train; packed serving "
               "weights + KV/state caches for serving).\n")
    out.append("| arch | shape | mesh | HLO flops/dev | HLO bytes/dev | "
               "HLO coll B/dev | state GiB/dev | compile s |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in recs:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{_f(r['flops_per_dev'])} | {_f(r['bytes_per_dev'])} | "
            f"{_f(r['collective_bytes_per_dev'])} | "
            f"{r.get('state_gib_per_dev', r['state_bytes_per_dev']/2**30):.2f} "
            f"| {r['compile_s']} |")
    if fails:
        out.append(f"\n**Failures ({len(fails)}):**\n")
        for a, s, mp, e in fails:
            out.append(f"- {a} × {s} multi_pod={mp}: `{e[:160]}`")

    out.append("""
### §Roofline — three terms per cell (single-pod, per training/serving step)

Constants: 197 TFLOP/s bf16/chip, 819 GB/s HBM/chip, 50 GB/s/link ICI.
Primary terms are **workload-intrinsic** (launch/analytic.py) because XLA's
`cost_analysis` visits `while` bodies once (inner pack/kv/chunk scans) and
its op-level "bytes accessed" counts fusion-internal operands; the HLO
columns above cross-check magnitudes. `roofline%` = t_compute / max(terms) —
the fraction of the binding resource's time doing model math.
`MODEL_FLOPS` = 6·N·D (train) / 2·N_active·D (serve); `useful/HLO` =
MODEL_FLOPS / (scan-corrected HLO FLOPs × chips) — how much compiled compute
is model math (catches remat/replication waste; decode cells are low because
batch-1/small-batch GEMV replicates work across the data axis).
""")
    out.append("| arch | shape | t_compute | t_memory | t_collective | "
               "dominant | roofline% | MODEL_FLOPS | useful/HLO | "
               "one-line diagnosis |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    diag = {
        "train_4k": "TP-16 activation all-reduces dominate (2/block, ×2 bwd)"
                    " — see §Perf cell C",
        "prefill_32k": "same TP all-reduce wall; LPSA keeps memory term low",
        "decode_32k": "weight + KV streaming (GEMV); TWD/LPSA cut it — §Perf"
                      " cell A",
        "long_500k": "O(TL_SA)/O(1) state ⇒ tiny terms; batch-1 replicates"
                     " compute across data axis (×16 redundancy)",
    }
    for r in recs:
        if r["mesh"] != "16x16" or "a_t_compute" not in r:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {_f(r['a_t_compute'])} | "
            f"{_f(r['a_t_memory'])} | {_f(r['a_t_collective'])} | "
            f"**{r['a_dominant']}** | {r['roofline_frac']:.1%} | "
            f"{_f(r['model_flops'])} | {r['useful_flops_frac']:.1%} | "
            f"{diag.get(r['shape'], '')} |")
    return "\n".join(out)


if __name__ == "__main__":
    print(render(sys.argv[1]))
