"""Elastic scaling: restore any checkpoint onto any mesh.

Checkpoints store logically-global arrays, so growing/shrinking the job
(node loss without replacement, or scale-up) is a restore with the new
mesh's NamedShardings.  `plan_remesh` picks the largest valid mesh for a
surviving device count (keeps the model axis intact first — TP degree is a
correctness-of-fit constraint, DP is free to shrink).
"""

from __future__ import annotations

from typing import Any

from repro.checkpoint.ckpt import restore_checkpoint

__all__ = ["plan_remesh", "elastic_restore"]


def plan_remesh(n_devices: int, *, model: int = 16,
                axis_names=("data", "model")) -> tuple[tuple[int, int], tuple]:
    """Largest (data, model) mesh fitting n_devices, preserving TP degree."""
    while model > 1 and n_devices % model:
        model //= 2
    data = max(1, n_devices // model)
    return (data, model), axis_names


def elastic_restore(directory: str, mesh, specs: Any, step: int | None = None):
    """Resharding restore onto `mesh` — the elastic entry point."""
    return restore_checkpoint(directory, step, mesh=mesh, specs=specs)
