"""Fault tolerance: checkpoint/restart loop, failure injection, stragglers.

At 1000+ nodes the MTBF of the job is minutes-to-hours; the framework
survives by (i) periodic sharded checkpoints (repro.checkpoint), (ii) a
restartable step loop that reloads the last good step on any worker fault,
and (iii) a straggler monitor flagging slow steps (EWMA z-score) so the
launcher can hot-swap the offending host.  Failures are injected in tests
via `FaultInjector` (deterministic schedule) — the loop must converge to
exactly the same parameters as a fault-free run (test_fault.py).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

__all__ = ["FaultInjector", "StragglerMonitor", "resilient_loop", "WorkerFailure"]


class WorkerFailure(RuntimeError):
    """Simulated node loss (preemption, ICI link flap, host OOM)."""


@dataclass
class FaultInjector:
    """Deterministically raise WorkerFailure before the given step indices."""
    fail_at: tuple[int, ...] = ()
    fired: set = field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise WorkerFailure(f"injected failure at step {step}")


@dataclass
class StragglerMonitor:
    """EWMA step-time tracker; flags steps slower than mean + k*std."""
    alpha: float = 0.1
    k: float = 3.0
    warmup: int = 5
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            self.mean = dt if self.n == 1 else \
                (1 - self.alpha) * self.mean + self.alpha * dt
            return False
        is_straggler = dt > self.mean + self.k * max(self.var, 1e-12) ** 0.5
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        if is_straggler:
            self.flagged.append((step, dt))
        return is_straggler


def resilient_loop(
    *,
    init_state: Any,
    step_fn: Callable[[Any, int], Any],
    n_steps: int,
    save_fn: Callable[[Any, int], None],
    restore_fn: Callable[[], tuple[Any, int]],
    ckpt_every: int = 10,
    injector: FaultInjector | None = None,
    monitor: StragglerMonitor | None = None,
    max_restarts: int = 8,
) -> tuple[Any, dict]:
    """Run step_fn n_steps times, checkpointing and surviving failures.

    restore_fn() -> (state, next_step); save_fn(state, step) persists state
    *after* `step` completed.  On WorkerFailure the loop restores the last
    checkpoint and replays — the data pipeline must be step-keyed so replay
    is deterministic (repro.data.pipeline seeds by step).
    """
    state, step = init_state, 0
    restarts = 0
    save_fn(state, 0)
    while step < n_steps:
        try:
            if injector is not None:
                injector.maybe_fail(step)
            t0 = time.monotonic()
            state = step_fn(state, step)
            dt = time.monotonic() - t0
            if monitor is not None:
                monitor.observe(step, dt)
            step += 1
            if step % ckpt_every == 0:
                save_fn(state, step)
        except WorkerFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            state, step = restore_fn()
    save_fn(state, n_steps)
    stats = {"restarts": restarts,
             "stragglers": list(monitor.flagged) if monitor else []}
    return state, stats
