"""Collective helpers: compressed gradient exchange + overlap utilities.

The cross-pod (DCN) gradient reduction is the slowest collective at 1000+
node scale; `compressed_psum` trades it down 4x by shipping int8 + per-shard
scales (with error feedback held by the caller so quantization noise is
unbiased over steps — the standard 1-bit-Adam/PowerSGD recipe at int8).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["int8_compress", "int8_decompress", "compressed_psum",
           "psum_scatter_mean"]


def int8_compress(x: jax.Array):
    """-> (q int8, scale f32 scalar) with absmax scaling."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = (amax / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return q.astype(dtype) * scale.astype(dtype)


def compressed_psum(x: jax.Array, axis_name: str, error: jax.Array):
    """int8-compressed all-reduce with error feedback.

    Inside shard_map/pmap: each shard quantizes (x + error) to int8, the
    *wire tensor is int8* (all_gather), shards dequantize-and-sum locally.
    Returns (summed f32, new_error).  Collective bytes: N vs 4N for f32
    ring all-reduce halves (~4x with P large).
    """
    target = x + error
    q, scale = int8_compress(target)
    new_error = target - int8_decompress(q, scale, x.dtype)
    qg = jax.lax.all_gather(q, axis_name)          # (P, ...) int8 on the wire
    sg = jax.lax.all_gather(scale, axis_name)      # (P,) f32
    summed = jnp.tensordot(sg.astype(jnp.float32),
                           qg.astype(jnp.float32), axes=1)
    return summed.astype(x.dtype), new_error


def psum_scatter_mean(x: jax.Array, axis_name: str):
    """reduce-scatter mean along axis 0 (the ZeRO-1 gradient exchange)."""
    n = jax.lax.axis_size(axis_name)
    return jax.lax.psum_scatter(x, axis_name, scatter_dimension=0,
                                tiled=True) / n
