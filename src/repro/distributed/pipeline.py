"""Pipeline parallelism over the "pod" axis (GPipe schedule via shard_map).

The multi-pod mesh (pod=2, data=16, model=16) can run the pod axis as DP
(default) or as a 2-stage pipeline: each pod holds half the layer groups;
activations flow pod0 -> pod1 through `ppermute` (DCN), microbatched so the
bubble is 1/(M+1).  Implemented generically for S stages / M microbatches;
autodiff works through ppermute (its transpose is the reverse permute), so
the same schedule serves training.

This is a *feature module*: launch/train.py enables it with --pp, and
tests/test_pipeline.py checks S-stage == single-device numerics.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_apply", "split_stages"]


def split_stages(seq: tuple, n_stages: int) -> tuple:
    """Split a tuple of layer-params into n_stages contiguous chunks."""
    n = len(seq)
    per = (n + n_stages - 1) // n_stages
    return tuple(seq[i * per:(i + 1) * per] for i in range(n_stages))


def pipeline_apply(stage_fn: Callable, stage_params, x: jax.Array, *,
                   mesh, axis: str = "pod", n_microbatches: int = 4):
    """Run x through S pipeline stages sharded on `axis`.

    stage_fn(params_for_stage, x_mb) -> y_mb, applied per microbatch.
    stage_params: pytree whose leaves have a leading S axis (stage-stacked).
    x: (B, ...) with B divisible by n_microbatches.

    Returns y with the same shape as x.  GPipe schedule: T = M + S - 1 ticks;
    at each tick every stage processes one in-flight microbatch and the
    boundary activation hops stages via ppermute.
    """
    n_stages = mesh.shape[axis]
    m = n_microbatches
    b = x.shape[0]
    if b % m:
        raise ValueError(f"batch {b} not divisible by microbatches {m}")

    def local(params_local, x_local):
        # params_local: this stage's params — shard_map keeps the sharded
        # stage axis with local extent 1; squeeze it off
        params_local = jax.tree.map(lambda t: t[0], params_local)
        # x_local: full batch (replicated across the pod axis)
        sid = jax.lax.axis_index(axis)
        mbs = x_local.reshape((m, b // m) + x_local.shape[1:])
        ticks = m + n_stages - 1
        zero = jnp.zeros_like(mbs[0])
        carry_in = zero        # activation arriving from the previous stage
        outs = jnp.zeros_like(mbs)

        def tick(t, state):
            carry_in, outs = state
            # stage 0 injects microbatch t (when in range); others consume
            mb_idx = jnp.clip(t, 0, m - 1)
            inject = jnp.where(t < m, 1.0, 0.0)
            x_in = jnp.where(sid == 0, mbs[mb_idx] * inject, carry_in)
            y = stage_fn(params_local, x_in)
            # pass to next stage; last stage's output is collected
            carry_out = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(n_stages - 1)])
            done_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            take = (sid == n_stages - 1) & (t >= n_stages - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(take, y, outs[done_idx]), done_idx, 0)
            return carry_out, outs

        carry_in, outs = jax.lax.fori_loop(0, ticks, tick, (carry_in, outs))
        # broadcast final outputs from the last stage to all pods
        outs = jax.lax.psum(jnp.where(sid == n_stages - 1, outs, 0.0), axis)
        return outs.reshape(x_local.shape)

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    from repro.distributed.sharding import shard_map
    return shard_map(
        local, mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x)
