"""Topology + ShardingPlan: the one-stop distributed layout API.

``distributed/`` grew as a bag of helpers (``param_specs``, ``zero1_specs``,
``batch_spec``, ``make_production_mesh``, ``dp_axes_for``) that training
could stitch together but serving could not consume.  This module
consolidates them into two frozen objects:

  * ``Topology`` — the logical mesh: (pods, dp, tp) extents, axis names,
    predicate helpers (``model_divides``, ``dp_axes_for``), mesh
    construction with an actionable error when the host is short on
    devices, and ``shrink()`` for elastic recovery after device loss.
  * ``ShardingPlan`` — param + cache + batch PartitionSpecs resolved once
    per config/tree, validated against the actual pytree (every sharded
    dim must divide by its axis extent), convertible to ``NamedSharding``
    trees for explicit jit in/out shardings, and reprintable
    (``describe()``) for debugging.

TWD base-3 packed slabs inherit their master weight's spec (see
``distributed/sharding.py``'s K-packing note): an N-dim "model" shard never
splits a packed byte, and the packed K dim is 16-row aligned so a K shard
stays byte-aligned for any tp <= 16.

The legacy helpers remain as warn-once ``DeprecationWarning`` shims in
``sharding.py`` / ``launch/mesh.py``; new code goes through
``ShardingPlan.for_config(cfg)`` / ``Topology``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as _rules

__all__ = ["Topology", "ShardingPlan"]


# -------------------------------------------------------------------------
# Topology
# -------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Topology:
    """Logical device mesh: ``dp`` data-parallel x ``tp`` tensor-parallel
    ways, optionally replicated over ``pods``.  Frozen and hashable so it
    can ride inside ``ServeConfig`` and jit closure state."""

    dp: int = 1
    tp: int = 1
    pods: int = 1

    def __post_init__(self):
        for name in ("dp", "tp", "pods"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"Topology.{name} must be an int >= 1, "
                                 f"got {v!r}")

    # -- shape/axes --------------------------------------------------------

    @property
    def axis_names(self) -> tuple[str, ...]:
        return (("pod", "data", "model") if self.pods > 1
                else ("data", "model"))

    @property
    def shape(self) -> tuple[int, ...]:
        return ((self.pods, self.dp, self.tp) if self.pods > 1
                else (self.dp, self.tp))

    @property
    def n_devices(self) -> int:
        return self.pods * self.dp * self.tp

    @property
    def dp_axes(self) -> tuple[str, ...]:
        return ("pod", "data") if self.pods > 1 else ("data",)

    @property
    def dp_extent(self) -> int:
        return self.pods * self.dp

    def axis_size(self, axis: str) -> int:
        return {"pod": self.pods, "data": self.dp, "model": self.tp}[axis]

    # -- predicates --------------------------------------------------------

    def model_divides(self, dim: int) -> bool:
        """Can `dim` be split over the model axis?"""
        return dim > 0 and dim % self.tp == 0

    def dp_axes_for(self, global_batch: int) -> tuple[str, ...]:
        """Data-parallel axes usable for this batch (batch 1 => replicate).
        Accumulates pod then data while the batch stays divisible — the
        same contract as the legacy ``launch.mesh.dp_axes_for``."""
        dp = 1
        out = []
        for a in self.dp_axes:
            if global_batch % (dp * self.axis_size(a)) == 0:
                out.append(a)
                dp *= self.axis_size(a)
        return tuple(out)

    def batch_spec(self, *, sequence_sharded: bool = False) -> P:
        if sequence_sharded:
            return P(None, self.dp_axes)
        return P(self.dp_axes)

    # -- mesh construction -------------------------------------------------

    def build_mesh(self, devices=None):
        devs = tuple(jax.devices() if devices is None else devices)
        if len(devs) < self.n_devices:
            raise RuntimeError(
                f"Topology{self.shape} needs {self.n_devices} devices, have "
                f"{len(devs)} — relaunch with XLA_FLAGS="
                f"--xla_force_host_platform_device_count={self.n_devices} "
                f"(must be set before jax initializes) or shrink --tp/--dp")
        return jax.make_mesh(self.shape, self.axis_names,
                             devices=devs[:self.n_devices])

    @classmethod
    def from_mesh(cls, mesh) -> "Topology":
        dims = dict(zip(mesh.axis_names, np.shape(mesh.devices)))
        return cls(dp=int(dims.get("data", 1)), tp=int(dims.get("model", 1)),
                   pods=int(dims.get("pod", 1)))

    @classmethod
    def production(cls, *, multi_pod: bool = False) -> "Topology":
        """The 16x16 (or 2x16x16) production shape of launch/mesh.py."""
        return cls(dp=16, tp=16, pods=2 if multi_pod else 1)

    # -- elastic -----------------------------------------------------------

    def shrink(self, n_devices: int) -> "Topology":
        """Topology after losing devices: keep tp if it still divides the
        survivor count (halving it otherwise, per elastic.plan_remesh) and
        fold pods into a single flat data axis.  dp never grows."""
        from repro.distributed import elastic
        (data, model), _ = elastic.plan_remesh(
            max(1, int(n_devices)), model=self.tp)
        return dataclasses.replace(
            self, pods=1, dp=min(data, self.dp * self.pods), tp=model)


# -------------------------------------------------------------------------
# cache specs (serving KV / recurrent state, batch-wise + head-wise)
# -------------------------------------------------------------------------

def _cache_leaf_spec(path, leaf, topo: Topology, batch: int) -> P:
    """Spec for one serving-cache leaf.  Keyed on the leaf name (the cache
    trees are flat dicts per layer): slot/batch dim shards over the dp
    axes when divisible, head-ish dims over "model" when divisible."""
    names = _rules._names(path)
    name = names[-1] if names else ""
    stacked = "stacked" in names
    shape = tuple(leaf.shape)
    core = shape[1:] if stacked else shape
    nd = len(core)
    tp = topo.tp
    dp = (topo.dp_axes if topo.dp_extent > 1 and nd >= 1
          and core[0] == batch and batch % topo.dp_extent == 0 else None)

    def out(parts) -> P:
        parts = list(parts)[:nd] + [None] * (nd - len(parts))
        return P(*(((None,) + tuple(parts)) if stacked else tuple(parts)))

    if name == "pos_pages":
        return out([None] * nd)
    if name in ("k_pages", "v_pages") and nd == 4:
        m = "model" if tp > 1 and core[2] % tp == 0 else None
        return out([None, None, m, None])
    if name in ("k", "v") and nd == 4:
        for i in (2, 3):
            if tp > 1 and core[i] % tp == 0:
                parts = [dp, None, None, None]
                parts[i] = "model"
                return out(parts)
        return out([dp, None, None, None])
    if name == "conv" and nd == 3:
        m = "model" if tp > 1 and core[2] % tp == 0 else None
        return out([dp, None, m])
    if name in ("ssm", "wkv", "s") and nd == 4:
        parts = [dp, None, None, None]
        for i in (1, 2, 3):
            if tp > 1 and core[i] % tp == 0:
                parts[i] = "model"
                break
        return out(parts)
    # pos tables, shift buffers, ssd token buffers, page tables: batch-wise
    return out([dp] + [None] * (nd - 1))


# -------------------------------------------------------------------------
# ShardingPlan
# -------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """PartitionSpecs for one (topology, param tree[, cache tree]) triple,
    resolved once and reused for every jit placement."""

    topology: Topology
    params: Any                 # PartitionSpec pytree matching the params
    batch: P                    # (B, ...) activation spec
    caches: Any = None          # PartitionSpec pytree matching the caches

    # -- constructors ------------------------------------------------------

    @classmethod
    def for_tree(cls, tree: Any, topology: Topology | None = None,
                 *, validate: bool = True) -> "ShardingPlan":
        """Resolve specs against an existing param pytree (master or
        serving format — packed slabs inherit the master spec)."""
        topo = topology or Topology()
        specs = jax.tree_util.tree_map_with_path(_rules._leaf_spec, tree)
        plan = cls(topology=topo, params=specs, batch=topo.batch_spec())
        if validate:
            plan.validate(tree)
        return plan

    @classmethod
    def for_config(cls, cfg, topology: Topology | None = None,
                   *, serving: bool = True,
                   validate: bool = True) -> "ShardingPlan":
        """Resolve specs for a model config without materializing weights
        (``jax.eval_shape`` over init + export)."""
        from repro.models import model as MD

        def build():
            p = MD.init_params(jax.random.PRNGKey(0), cfg)
            return MD.export_serving(p, cfg) if serving else p
        tree = jax.eval_shape(build)
        return cls.for_tree(tree, topology, validate=validate)

    def with_caches(self, caches: Any, *, batch: int) -> "ShardingPlan":
        """Attach cache specs resolved against an actual cache pytree.
        ``batch`` is the slot count — the dp axes apply only to dims that
        equal it and divide by the dp extent."""
        topo = self.topology
        specs = jax.tree_util.tree_map_with_path(
            lambda pth, leaf: _cache_leaf_spec(pth, leaf, topo, batch),
            caches)
        return dataclasses.replace(self, caches=specs)

    # -- validation / inspection ------------------------------------------

    def _iter_spec_leaves(self, tree: Any):
        flat_s = jax.tree_util.tree_flatten_with_path(
            self.params, is_leaf=lambda x: isinstance(x, P))[0]
        flat_t = jax.tree_util.tree_flatten_with_path(tree)[0]
        if len(flat_s) != len(flat_t):
            raise ValueError(
                f"plan/tree structure mismatch: {len(flat_s)} specs vs "
                f"{len(flat_t)} leaves — re-resolve the plan for this tree")
        for (ps, spec), (pt, leaf) in zip(flat_s, flat_t):
            yield "/".join(_rules._names(pt)), spec, leaf

    def validate(self, tree: Any) -> "ShardingPlan":
        """Check every sharded dim divides its axis extent; raise with a
        per-leaf report otherwise.  Returns self for chaining."""
        bad = []
        for name, spec, leaf in self._iter_spec_leaves(tree):
            shape = tuple(getattr(leaf, "shape", ()))
            for i, axes in enumerate(tuple(spec)):
                if axes is None:
                    continue
                axes = (axes,) if isinstance(axes, str) else tuple(axes)
                ext = math.prod(self.topology.axis_size(a) for a in axes)
                if i >= len(shape) or shape[i] % ext != 0:
                    bad.append(f"  {name}: shape {shape} dim {i} not "
                               f"divisible by {'*'.join(axes)}={ext} "
                               f"(spec {spec})")
        if bad:
            raise ValueError(
                "ShardingPlan does not fit this tree on "
                f"Topology{self.topology.shape}:\n" + "\n".join(bad))
        return self

    def replicated_leaves(self, tree: Any, min_ndim: int = 2) -> list[str]:
        """Paths of >=min_ndim-D leaves whose spec is fully replicated —
        the fall-through set tests pin so rule gaps are loud."""
        out = []
        for name, spec, leaf in self._iter_spec_leaves(tree):
            if getattr(leaf, "ndim", 0) >= min_ndim \
                    and all(a is None for a in tuple(spec)):
                out.append(name)
        return out

    def describe(self, tree: Any = None) -> str:
        """Human-readable table of the resolved layout."""
        topo = self.topology
        lines = [f"Topology(pods={topo.pods}, dp={topo.dp}, tp={topo.tp}) "
                 f"axes={topo.axis_names} shape={topo.shape}",
                 f"batch spec: {self.batch}"]
        if tree is not None:
            for name, spec, leaf in self._iter_spec_leaves(tree):
                shape = tuple(getattr(leaf, "shape", ()))
                lines.append(f"  {name:48s} {str(shape):24s} {spec}")
        else:
            flat = jax.tree_util.tree_flatten_with_path(
                self.params, is_leaf=lambda x: isinstance(x, P))[0]
            for pth, spec in flat:
                lines.append(f"  {'/'.join(_rules._names(pth)):48s} {spec}")
        if self.caches is not None:
            lines.append("cache specs:")
            flat = jax.tree_util.tree_flatten_with_path(
                self.caches, is_leaf=lambda x: isinstance(x, P))[0]
            for pth, spec in flat:
                lines.append(f"  {'/'.join(_rules._names(pth)):48s} {spec}")
        return "\n".join(lines)

    # -- materialization ---------------------------------------------------

    def named(self, mesh) -> Any:
        """NamedSharding tree for the params (jit in_shardings)."""
        return jax.tree.map(lambda s: NamedSharding(mesh, s), self.params,
                            is_leaf=lambda x: isinstance(x, P))

    def cache_named(self, mesh) -> Any:
        if self.caches is None:
            raise ValueError("plan has no cache specs; call with_caches()")
        return jax.tree.map(lambda s: NamedSharding(mesh, s), self.caches,
                            is_leaf=lambda x: isinstance(x, P))

    def zero1(self, shapes: Any, *, data_axis: str = "data",
              base: Any = None) -> Any:
        """Optimizer-moment specs: params specs + ZeRO-1 data-axis shard,
        with the once-per-tree unsharded-bytes summary (see
        sharding._zero1_specs).  ``base`` overrides the starting spec tree
        (e.g. an already-ZeRO'd tree to stack a second axis onto)."""
        return _rules._zero1_specs(
            self.params if base is None else base, shapes,
            data_size=self.topology.axis_size(data_axis),
            data_axis=data_axis)
