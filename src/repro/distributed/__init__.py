"""Distributed runtime: topology/plan API, sharding rules, collectives,
PP, fault tolerance."""
from . import collectives, elastic, fault, pipeline, sharding  # noqa: F401
from . import plan  # noqa: F401  (after sharding: plan builds on its rules)
from .plan import ShardingPlan, Topology  # noqa: F401
