"""Distributed runtime: sharding rules, collectives, PP, fault tolerance."""
from . import collectives, elastic, fault, pipeline, sharding  # noqa: F401
