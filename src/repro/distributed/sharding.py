"""Sharding rules: parameter/activation PartitionSpecs for the production mesh.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
  * TP  — column-parallel projections shard their output dim on "model";
          row-parallel (wo / w_out) shard their input dim; the pair gives the
          Megatron pattern with one all-reduce per block half.
  * EP  — expert stacks shard experts on "model" (moe.py's shard_map psum).
  * DP  — batch shards on ("pod", "data"); ZeRO-1 shards optimizer moments
          further along "data" (zero1_specs).
  * Vocab — embedding/head shard the vocab dim on "model".

TWD-packed serving weights are packed along K (axis 0), so a packed leaf
inherits exactly the spec of its master weight: an N-dim ("model") shard
never splits a byte; a K-dim shard is padded by GSPMD (global decode is
written against logical K, so padding is inert).

Rules key on the nearest named ancestor in the param tree path; leaves under
the scan "stacked" stacks get a leading None for the group axis.
"""

from __future__ import annotations

import warnings
from typing import Any

import jax
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

__all__ = ["param_specs", "zero1_specs", "batch_spec", "MODEL_AXIS",
           "shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable shard_map.

    jax >= 0.6 exposes ``jax.shard_map`` with the ``check_vma`` kwarg;
    0.4.x only has ``jax.experimental.shard_map.shard_map`` where the same
    knob is spelled ``check_rep``.  Every shard_map in this repo goes
    through here so multi-device code runs on both.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)

MODEL_AXIS = "model"

# nearest-ancestor name -> spec for the 2D master weight (in, out)
COL_PARALLEL = {"wq", "wk", "wv", "wg", "wz", "wx", "w_gate", "w_in", "ck",
                "shared_gate", "shared_in", "wa2", "w_decay2", "head"}
ROW_PARALLEL = {"wo", "w_out", "cv", "shared_out"}
EXPERT = {"experts_gate", "experts_in", "experts_out"}
VOCAB = {"embed"}
# 1-D leaves laid out along the model-sharded inner dim
INNER_VEC = {"w0", "ln_x"}
REPLICATED = {"router", "u", "wb", "wc", "wdt", "dt_bias", "a_log", "d_skip",
              "w_decay1", "wa1", "mix_t", "mix_c", "cr", "norm1", "norm2",
              "final_norm", "conv"}


def _names(path) -> list[str]:
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        elif isinstance(k, SequenceKey):
            out.append(f"[{k.idx}]")
    return out


def _leaf_spec(path, leaf) -> P:
    names = _names(path)
    ndim = getattr(leaf, "ndim", 0)
    stacked = "stacked" in names
    base: tuple

    def with_stack(spec: tuple) -> P:
        spec = tuple(spec[:ndim - (1 if stacked else 0)])
        return P(*(((None,) + spec) if stacked else spec))

    leaf_name = names[-1] if names else ""
    anc = [n for n in names if not n.startswith("[")]
    hit = None
    for n in reversed(anc):
        if n in COL_PARALLEL | ROW_PARALLEL | EXPERT | VOCAB | INNER_VEC \
                | REPLICATED or n == "mamba":
            hit = n
            break

    if leaf_name == "scale" and ndim <= 1 and hit not in INNER_VEC:
        return P()  # quantization / norm scalars and (d,) norm scales
    if hit in VOCAB:
        return with_stack((MODEL_AXIS, None))
    if hit in COL_PARALLEL:
        if ndim - (1 if stacked else 0) <= 1:
            return P()
        return with_stack((None, MODEL_AXIS))
    if hit in ROW_PARALLEL:
        if ndim - (1 if stacked else 0) <= 1:
            return P()
        return with_stack((MODEL_AXIS, None))
    if hit in EXPERT:
        return with_stack((MODEL_AXIS, None, None))
    if hit in INNER_VEC:
        return with_stack((MODEL_AXIS,) + (None,) * 3)
    if hit == "mamba" and leaf_name == "conv":
        return with_stack((None, MODEL_AXIS))
    if hit == "mamba" and leaf_name == "scale":  # mamba gated-norm over d_inner
        return with_stack((MODEL_AXIS,))
    return with_stack((None,) * 4)


def _param_specs(params: Any) -> Any:
    return jax.tree_util.tree_map_with_path(_leaf_spec, params)


def _dtype_bytes(shape) -> int:
    dt = getattr(shape, "dtype", None)
    itemsize = getattr(dt, "itemsize", 4) if dt is not None else 4
    size = 1
    for d in shape.shape:
        size *= d
    return size * itemsize


def _zero1_specs(specs: Any, shapes: Any, data_size: int = 16,
                 data_axis: str = "data") -> Any:
    """ZeRO-1 impl: insert `data_axis` into the first unsharded dimension
    whose size divides by the data-axis extent.  Leaves with no such dim
    stay on their param spec (explicit input shardings require exact
    divisibility) — but that is no longer silent: one summary warning per
    tree reports how many moment leaves / bytes stay unsharded."""
    skipped: list[tuple[int, int]] = [0, 0]  # leaves, bytes

    def one(spec: P, shape) -> P:
        parts = list(spec)
        parts += [None] * (len(shape.shape) - len(parts))
        for i, s in enumerate(parts):
            if s is None and shape.shape[i] % data_size == 0 \
                    and shape.shape[i] > 0:
                parts[i] = data_axis
                return P(*parts)
        skipped[0] += 1
        skipped[1] += _dtype_bytes(shape)
        return spec
    out = jax.tree.map(one, specs, shapes,
                       is_leaf=lambda x: isinstance(x, P))
    if skipped[0]:
        warnings.warn(
            f"zero1_specs: {skipped[0]} moment leaves "
            f"({skipped[1] / 2**20:.2f} MiB per moment) have no dim "
            f"divisible by {data_axis}={data_size} and stay unsharded "
            f"(replicated across the data axis)", stacklevel=3)
    return out


def _batch_spec(multi_pod: bool, *, sequence_sharded: bool = False) -> P:
    dp = ("pod", "data") if multi_pod else ("data",)
    if sequence_sharded:
        return P(None, dp)
    return P(dp)


# --------------------------------------------------------------------------
# deprecated entry points — new code goes through distributed/plan.py
# --------------------------------------------------------------------------

_DEPRECATION_WARNED: set = set()


def _warn_deprecated(old: str, new: str) -> None:
    if old not in _DEPRECATION_WARNED:   # once per process, not per trace
        _DEPRECATION_WARNED.add(old)
        warnings.warn(
            f"{old} is deprecated; use {new} (distributed/plan.py)",
            DeprecationWarning, stacklevel=3)


def param_specs(params: Any) -> Any:
    """Deprecated: use ``ShardingPlan.for_config(cfg)`` /
    ``ShardingPlan.for_tree(params)``."""
    _warn_deprecated("param_specs", "ShardingPlan.for_tree(params).params")
    return _param_specs(params)


def zero1_specs(specs: Any, shapes: Any, data_size: int = 16,
                data_axis: str = "data") -> Any:
    """Deprecated: use ``ShardingPlan.zero1(shapes)``."""
    _warn_deprecated("zero1_specs", "ShardingPlan.zero1(shapes)")
    return _zero1_specs(specs, shapes, data_size, data_axis)


def batch_spec(multi_pod: bool, *, sequence_sharded: bool = False) -> P:
    """Deprecated: use ``Topology.batch_spec()``."""
    _warn_deprecated("batch_spec", "Topology.batch_spec()")
    return _batch_spec(multi_pod, sequence_sharded=sequence_sharded)
