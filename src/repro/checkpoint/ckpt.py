"""Sharded checkpointing: npz payload + manifest, async save, resharding.

Layout:  <dir>/step_<N>/payload.npz   (flat leaf arrays, keyed by index)
         <dir>/step_<N>/manifest.pkl  (treedef + paths + shapes + dtypes)
         <dir>/step_<N>/DONE          (commit marker -> crash-safe)

Single-process semantics here (the container has one host); the format is
already shard-ready: every leaf is stored full-size and `restore` places it
onto any mesh via NamedSharding — which is exactly what elastic re-scaling
needs (distributed.elastic).  Async mode hands the write to a daemon thread
so the train loop is not blocked by I/O (the classic "emergency checkpoint"
pattern); `wait_pending` joins before the next save.
"""

from __future__ import annotations

import os
import pickle
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "wait_pending"]

_PENDING: list[threading.Thread] = []


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def _write(directory: str, step: int, leaves, treedef) -> None:
    d = _step_dir(directory, step)
    tmp = d + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "payload.npz"),
             **{f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)})
    with open(os.path.join(tmp, "manifest.pkl"), "wb") as f:
        pickle.dump({"treedef": treedef, "step": step,
                     "n_leaves": len(leaves)}, f)
    with open(os.path.join(tmp, "DONE"), "w") as f:
        f.write("ok")
    if os.path.isdir(d):
        shutil.rmtree(d)
    os.rename(tmp, d)


def wait_pending() -> None:
    while _PENDING:
        _PENDING.pop().join()


def save_checkpoint(directory: str, step: int, tree: Any, *,
                    async_save: bool = False) -> str:
    """Persist a pytree.  Returns the step directory path."""
    wait_pending()
    leaves, treedef = jax.tree.flatten(tree)
    leaves = [np.asarray(jax.device_get(x)) for x in leaves]
    if async_save:
        t = threading.Thread(target=_write,
                             args=(directory, step, leaves, treedef),
                             daemon=True)
        t.start()
        _PENDING.append(t)
    else:
        _write(directory, step, leaves, treedef)
    return _step_dir(directory, step)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(directory, name, "DONE")):
            steps.append(int(name[len("step_"):]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int | None = None, *,
                       mesh=None, specs: Any = None) -> tuple[Any, int]:
    """Load a pytree; optionally place leaves on `mesh` with `specs`
    (resharding restore — the mesh may differ from the one that saved)."""
    wait_pending()
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = _step_dir(directory, step)
    with open(os.path.join(d, "manifest.pkl"), "rb") as f:
        man = pickle.load(f)
    payload = np.load(os.path.join(d, "payload.npz"))
    leaves = [payload[f"leaf_{i}"] for i in range(man["n_leaves"])]
    tree = jax.tree.unflatten(man["treedef"], leaves)
    if mesh is not None and specs is not None:
        from jax.sharding import NamedSharding
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            tree, specs)
    return tree, step
