"""Sharded checkpointing with resharding restore."""
from .ckpt import latest_step, restore_checkpoint, save_checkpoint, wait_pending  # noqa: F401
