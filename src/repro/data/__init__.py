"""Deterministic sharded data pipelines (synthetic + file-backed)."""
from . import pipeline  # noqa: F401
