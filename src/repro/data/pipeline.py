"""Data pipeline: deterministic sharded token streams with prefetch.

Two sources behind one interface:
  * SyntheticLM  — a seeded Zipf-ish token stream with local n-gram structure
    (so tiny models have something learnable for the Table-II benches);
  * FileTokens   — memory-mapped binary token file (uint16/uint32), chunked
    into (batch, seq+1) windows.

Determinism contract (fault tolerance): `batch(step)` is a pure function of
(seed, step, shard), so checkpoint-restart replays identical batches and the
resilient loop converges to the fault-free parameters (test_fault.py).
Prefetch runs a daemon thread keeping a small queue of ready batches.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np

__all__ = ["SyntheticLM", "FileTokens", "Prefetcher", "make_batch_fn"]


@dataclass(frozen=True)
class SyntheticLM:
    """Deterministic synthetic language: Zipf unigrams + bigram coupling.

    next-token = f(prev) with probability `coupling`, else Zipf sample —
    learnable structure whose PPL floor a tiny model can approach.
    """
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    coupling: float = 0.7
    shard: int = 0
    n_shards: int = 1

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        b = self.batch // self.n_shards
        # zipf unigram draws clipped to vocab
        base = rng.zipf(1.3, size=(b, self.seq_len + 1))
        base = (base - 1) % self.vocab
        # deterministic bigram map: f(t) = (a*t + c) % V
        f = (base * 31 + 17) % self.vocab
        use_bigram = rng.random((b, self.seq_len + 1)) < self.coupling
        toks = base.copy()
        for t in range(1, self.seq_len + 1):
            toks[:, t] = np.where(use_bigram[:, t],
                                  (toks[:, t - 1] * 31 + 17) % self.vocab,
                                  base[:, t])
        return {"inputs": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


@dataclass(frozen=True)
class FileTokens:
    """Binary token file source: shard-strided windows, step-keyed."""
    path: str
    vocab: int
    seq_len: int
    batch: int
    dtype: str = "uint16"
    shard: int = 0
    n_shards: int = 1

    def _mm(self) -> np.ndarray:
        return np.memmap(self.path, dtype=self.dtype, mode="r")

    def batch_at(self, step: int) -> dict:
        data = self._mm()
        b = self.batch // self.n_shards
        span = self.seq_len + 1
        n_windows = len(data) // span
        idx = (step * self.batch + self.shard * b + np.arange(b)) % n_windows
        rows = np.stack([data[i * span:(i + 1) * span] for i in idx])
        rows = rows.astype(np.int64) % self.vocab
        return {"inputs": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}


def make_batch_fn(source) -> "callable":
    return source.batch_at


class Prefetcher:
    """Daemon-thread prefetch of step-keyed batches (depth-bounded queue)."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.next_step = start_step
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._run, daemon=True)
        self.t.start()

    def _run(self):
        step = self.next_step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            self.q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
        try:
            self.q.get_nowait()
        except queue.Empty:
            pass
