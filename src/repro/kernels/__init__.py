"""Pallas TPU kernels for the paper's compute hot-spots.

  ternary_gemm : fused TWD(base-3) decode + ternary mpGEMM (STL analogue)
  das_gemm     : DAS block-compacted sparse GEMV (butterfly -> scatter)
  sparse_attn  : LPSA sink+window flash attention
  topk_mask    : DAS ASM bitmask generator

ops.py = jit'd dispatch wrappers (pallas on TPU, jnp ref elsewhere);
ref.py = pure-jnp oracles the kernels are verified against.
"""
