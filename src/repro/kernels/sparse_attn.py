"""Pallas kernel: LPSA sink+window flash attention (paper Sec. IV-B).

Single flash-style pass with the StreamingLLM mask (attention sink + local
window): per (head, q-block) the kernel sweeps key blocks with an online
softmax; scores and softmax statistics never leave VMEM — the TPU version of
the paper's claim that LPSA keeps attention intermediates off DRAM.

Supports GQA (q heads index kv heads via h // n_rep) and Gemma-style logit
soft-capping.  Positions are explicit arrays so the same kernel serves
prefill packs (contiguous positions) and the ring-buffer decode cache
(arbitrary slot->position maps, -1 = empty slot).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, out_ref,
                 m_scr, l_scr, acc_scr, *, n_kb: int, sink: int, window: int,
                 softcap: float | None, scale: float):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)               # (bq, D)
    k = k_ref[0].astype(jnp.float32)               # (bk, D)
    v = v_ref[0].astype(jnp.float32)
    qp = qpos_ref[...]                             # (bq, 1) int32
    kp = kpos_ref[...]                             # (1, bk) int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    mask = (kp <= qp) & ((kp < sink) | (qp - kp < window)) & (kp >= 0)
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked running max so exp() stays finite
    m_safe = jnp.where(m_new <= NEG_INF, 0.0, m_new)
    alpha = jnp.where(m_prev <= NEG_INF, 0.0, jnp.exp(m_prev - m_safe))
    p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kb == n_kb - 1)
    def _finalize():
        l = l_scr[...]
        out_ref[0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)).astype(
            out_ref.dtype)


def sparse_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     q_pos: jax.Array, k_pos: jax.Array, *, sink: int,
                     window: int, softcap: float | None = None,
                     block_q: int = 128, block_k: int = 128,
                     interpret: bool = False) -> jax.Array:
    """q: (Hq, Lq, D); k, v: (Hkv, Lk, D); q_pos: (Lq,); k_pos: (Lk,).

    Returns (Hq, Lq, D) in q.dtype.  Batch is vmapped by the wrapper.
    """
    hq, lq, d = q.shape
    hkv, lk, _ = k.shape
    if hq % hkv:
        raise ValueError(f"GQA requires Hq % Hkv == 0, got {hq}, {hkv}")
    n_rep = hq // hkv
    # tile shapes are autotuner parameters — degrade to divisors so every
    # candidate is runnable on awkward ring-cache lengths
    bq = min(block_q, lq)
    while lq % bq:
        bq -= 1
    bk = min(block_k, lk)
    while lk % bk:
        bk -= 1
    n_kb = lk // bk

    kernel = functools.partial(
        _attn_kernel, n_kb=n_kb, sink=sink, window=window, softcap=softcap,
        scale=1.0 / (d ** 0.5))
    return pl.pallas_call(
        kernel,
        grid=(hq, lq // bq, n_kb),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h // n_rep, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h // n_rep, j, 0)),
            pl.BlockSpec((bq, 1), lambda h, i, j: (i, 0)),
            pl.BlockSpec((1, bk), lambda h, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, q_pos.astype(jnp.int32)[:, None],
      k_pos.astype(jnp.int32)[None, :])
