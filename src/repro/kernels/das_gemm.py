"""Pallas kernels: DAS block-sparse ternary GEMV/GEMM (paper Sec. III-C/D/E).

The STL core consumes *compacted* activations — per 32-lane block only the
Top-K survive — and a butterfly router steers the matching weight channels.
On TPU the router becomes a block-local one-hot **scatter**: the compacted
values are expanded back to their dense lane positions inside VMEM (a VPU
compare-select over a 32-wide block, negligible next to the MXU dot), then a
dense slab dot runs on the MXU.  HBM sees only the compacted activations
(S_a x fewer bytes) — the bandwidth side of DAS — while the FLOP saving of
the butterfly does not transfer to a dense systolic array (DESIGN.md §2).

Two kernels:

  * ``das_gemv``         — single-token GEMV against *unpacked* int8 trits
    (the paper's "STL core is optimized for GEMV" decode shape; batch rows
    vmapped by the caller).
  * ``das_ternary_gemm`` — the fused serving path: batched compacted
    activations routed straight against weights that *stay base-3 packed in
    HBM*.  Each K tile is the paper's 64B:80B slab (320 trits = 64 packed
    bytes): the VPU scatters the compacted values block-locally and decodes
    the packed slab while the MXU consumes the previous one.  This is the
    composition of DAS and TWD in one datapath — dense activations never
    round-trip through HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ternary_gemm import (K_SLAB, KP_SLAB, TRITS_PER_BYTE,
                                        _decode_block)

K_TILE = 512          # dense lanes per K tile (das_gemv)
BLOCK = 32            # DAS block size B_s


def _das_gemv_kernel(vals_ref, idx_ref, w_ref, wscale_ref, out_ref, *,
                     n_k: int, keep: int):
    """grid = (N/bn, K/K_TILE); one token.

    vals/idx: (1, bkc) compacted activation slab (bkc = K_TILE*keep/BLOCK),
    w: (K_TILE, bn) int8 trits, out: (1, bn) f32.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[...].astype(jnp.float32)       # (1, bkc)
    local = idx_ref[...] - k * K_TILE              # absolute -> tile-local
    # scatter to dense lanes: onehot (bkc, K_TILE) — the "butterfly router"
    lanes = jax.lax.broadcasted_iota(jnp.int32, (local.shape[1], K_TILE), 1)
    onehot = (local[0, :, None] == lanes).astype(jnp.float32)
    dense = jax.lax.dot(vals, onehot,
                        preferred_element_type=jnp.float32)   # (1, K_TILE)
    w = w_ref[...].astype(jnp.float32)
    out_ref[...] += jax.lax.dot(dense, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finalize():
        out_ref[...] = out_ref[...] * wscale_ref[0, 0]


def das_gemv(values: jax.Array, indices: jax.Array, w_trits: jax.Array,
             w_scale: jax.Array, *, keep: int = BLOCK // 2,
             block_n: int = 256, interpret: bool = False) -> jax.Array:
    """(Kc,) compacted values/indices  x  (K, N) trits  ->  (N,) f32.

    Kc = K * keep / BLOCK; indices must be block-sorted ascending (the
    output of core.das.das_compact).
    """
    (kc,) = values.shape
    kdim, n = w_trits.shape
    if kc * BLOCK != kdim * keep:
        raise ValueError(f"Kc={kc} inconsistent with K={kdim}, keep={keep}")
    if kdim % K_TILE:
        raise ValueError(f"K={kdim} must be a multiple of {K_TILE}")
    bkc = K_TILE * keep // BLOCK
    bn = min(block_n, n)
    if n % bn:
        raise ValueError(f"N={n} not tileable by {bn}")
    n_k = kdim // K_TILE

    kernel = functools.partial(_das_gemv_kernel, n_k=n_k, keep=keep)
    out = pl.pallas_call(
        kernel,
        grid=(n // bn, n_k),
        in_specs=[
            pl.BlockSpec((1, bkc), lambda j, k: (0, k)),
            pl.BlockSpec((1, bkc), lambda j, k: (0, k)),
            pl.BlockSpec((K_TILE, bn), lambda j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(values[None, :], indices[None, :].astype(jnp.int32), w_trits,
      jnp.asarray(w_scale, jnp.float32).reshape(1, 1))
    return out[0]


# ---------------------------------------------------------------------------
# das_ternary_gemm: fused DAS scatter + TWD decode + matmul (serving path)
# ---------------------------------------------------------------------------

def _das_ternary_gemm_kernel(vals_ref, idx_ref, p_ref, wscale_ref, out_ref, *,
                             n_k: int, keep: int, block: int, k_tile: int):
    """grid = (M/bm, N/bn, K/k_tile) with k_tile a multiple of K_SLAB.

    vals/idx: (bm, bkc) compacted slab (bkc = k_tile*keep/block),
    p: (k_tile/5, bn) uint8 base-3 packed weights, out: (bm, bn) f32.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[...].astype(jnp.float32)        # (bm, bkc)
    local = idx_ref[...] - k * k_tile               # absolute -> tile-local
    bm, bkc = vals.shape
    nb = k_tile // block                            # DAS blocks per K tile
    # block-local scatter (the butterfly router): every compacted column c
    # belongs to block c // keep, so only a `block`-wide compare is needed —
    # keep == block degrades to the identity permutation (dense fallback).
    vals_b = vals.reshape(bm * nb, keep)
    loc_b = local.reshape(bm * nb, keep) % block    # in-block lane ids
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, 1, block), 2)
    hit = loc_b[:, :, None] == lanes                # (bm*nb, keep, block)
    dense = jnp.sum(jnp.where(hit, vals_b[:, :, None], 0.0), axis=1)
    dense = dense.reshape(bm, k_tile)
    # TWD decode of the 64B:80B slab(s) on the VPU, then the MXU slab dot
    w = _decode_block(p_ref[...]).astype(jnp.float32)   # (k_tile, bn)
    out_ref[...] += jax.lax.dot(dense, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finalize():
        out_ref[...] = out_ref[...] * wscale_ref[0, 0]


def das_ternary_gemm(values: jax.Array, indices: jax.Array,
                     packed: jax.Array, w_scale: jax.Array, *, keep: int,
                     block: int = BLOCK, block_m: int = 8,
                     block_n: int = 256, block_k: int = 1,
                     interpret: bool = False) -> jax.Array:
    """(M, Kc) compacted values/indices  x  base-3 packed (K/5, N) -> (M, N).

    Kc = K * keep / block; indices are absolute K-lane ids, block-sorted
    ascending (core.das.das_compact output).  K must tile by the 320-trit
    (64-byte) TWD slab and `block` must divide the slab.  Weights stay
    packed in HBM; activations enter compacted — the fused DAS+TWD datapath.
    Tile shapes are autotuner parameters: ``block_m``/``block_n`` degrade to
    divisors of M/N, ``block_k`` is the number of 320-trit slabs scattered +
    decoded per K step (degraded to a divisor of K/320).
    """
    m, kc = values.shape
    kp, n = packed.shape
    kdim = kp * TRITS_PER_BYTE
    if kc * block != kdim * keep:
        raise ValueError(f"Kc={kc} inconsistent with K={kdim}, keep={keep}, "
                         f"block={block}")
    if kdim % K_SLAB:
        raise ValueError(f"K={kdim} must be a multiple of the {K_SLAB}-trit slab")
    if K_SLAB % block:
        raise ValueError(f"DAS block {block} must divide the {K_SLAB}-trit slab")
    if not (0 < keep <= block):
        raise ValueError(f"keep={keep} out of range for block {block}")
    n_slab = kdim // K_SLAB
    bk = max(1, min(block_k, n_slab))
    while n_slab % bk:
        bk -= 1
    k_tile = bk * K_SLAB
    bkc = k_tile // block * keep
    bm = min(block_m, m)
    while m % bm:
        bm -= 1
    bn = min(block_n, n)
    while n % bn:
        bn -= 1
    n_k = n_slab // bk

    kernel = functools.partial(_das_ternary_gemm_kernel, n_k=n_k, keep=keep,
                               block=block, k_tile=k_tile)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bkc), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bkc), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk * KP_SLAB, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(values, indices.astype(jnp.int32), packed,
      jnp.asarray(w_scale, jnp.float32).reshape(1, 1))
