"""Pallas kernel: DAS block-sparse ternary GEMV (paper Sec. III-C/D).

The STL core consumes *compacted* activations — per 32-lane block only the
Top-K survive — and a butterfly router steers the matching weight channels.
On TPU the router becomes a block-local one-hot **scatter**: the compacted
values are expanded back to their dense lane positions inside VMEM (a VPU
one-hot matmul over a 32-wide block, negligible next to the MXU dot), then a
dense slab dot runs on the MXU.  HBM sees only the compacted activations
(S_a x fewer bytes) — the bandwidth side of DAS — while the FLOP saving of
the butterfly does not transfer to a dense systolic array (DESIGN.md §2).

GEMV-shaped on purpose: the paper's STL core "is optimized for GEMV" (decode
stage of one-batch inference); batch rows are vmapped by the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

K_TILE = 512          # dense lanes per K tile
BLOCK = 32            # DAS block size B_s


def _das_gemv_kernel(vals_ref, idx_ref, w_ref, wscale_ref, out_ref, *,
                     n_k: int, keep: int):
    """grid = (N/bn, K/K_TILE); one token.

    vals/idx: (1, bkc) compacted activation slab (bkc = K_TILE*keep/BLOCK),
    w: (K_TILE, bn) int8 trits, out: (1, bn) f32.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[...].astype(jnp.float32)       # (1, bkc)
    local = idx_ref[...] - k * K_TILE              # absolute -> tile-local
    # scatter to dense lanes: onehot (bkc, K_TILE) — the "butterfly router"
    lanes = jax.lax.broadcasted_iota(jnp.int32, (local.shape[1], K_TILE), 1)
    onehot = (local[0, :, None] == lanes).astype(jnp.float32)
    dense = jax.lax.dot(vals, onehot,
                        preferred_element_type=jnp.float32)   # (1, K_TILE)
    w = w_ref[...].astype(jnp.float32)
    out_ref[...] += jax.lax.dot(dense, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finalize():
        out_ref[...] = out_ref[...] * wscale_ref[0, 0]


def das_gemv(values: jax.Array, indices: jax.Array, w_trits: jax.Array,
             w_scale: jax.Array, *, keep: int = BLOCK // 2,
             block_n: int = 256, interpret: bool = False) -> jax.Array:
    """(Kc,) compacted values/indices  x  (K, N) trits  ->  (N,) f32.

    Kc = K * keep / BLOCK; indices must be block-sorted ascending (the
    output of core.das.das_compact).
    """
    (kc,) = values.shape
    kdim, n = w_trits.shape
    if kc * BLOCK != kdim * keep:
        raise ValueError(f"Kc={kc} inconsistent with K={kdim}, keep={keep}")
    if kdim % K_TILE:
        raise ValueError(f"K={kdim} must be a multiple of {K_TILE}")
    bkc = K_TILE * keep // BLOCK
    bn = min(block_n, n)
    if n % bn:
        raise ValueError(f"N={n} not tileable by {bn}")
    n_k = kdim // K_TILE

    kernel = functools.partial(_das_gemv_kernel, n_k=n_k, keep=keep)
    out = pl.pallas_call(
        kernel,
        grid=(n // bn, n_k),
        in_specs=[
            pl.BlockSpec((1, bkc), lambda j, k: (0, k)),
            pl.BlockSpec((1, bkc), lambda j, k: (0, k)),
            pl.BlockSpec((K_TILE, bn), lambda j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(values[None, :], indices[None, :].astype(jnp.int32), w_trits,
      jnp.asarray(w_scale, jnp.float32).reshape(1, 1))
    return out[0]
