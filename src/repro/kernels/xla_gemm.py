"""XLA-native ternary decode-GEMMs — the tuned CPU/GPU serving datapath.

Compiled Pallas is TPU/GPU-only; on an XLA-CPU backend the Pallas kernels
only run under `interpret=True` (orders of magnitude slower than XLA's own
codegen).  These implementations are the backend-appropriate realization of
the same TENET datapath — weights stay base-3 packed in memory and decode
fuses into the matmul — expressed as ops XLA compiles well.  The autotuner
(`kernels/autotune.py`) ranks them against the Pallas tile configs per
shape+backend and `tlin_apply(kernel_mode="tuned")` dispatches the winner.

The workhorse is the *strided 5-way split* decode (`f32dec_matmul`): byte
column g packs k-lanes 5g..5g+4, digit j of every byte belongs to x column
j::5, so

    for j in 0..4:  q = floor(p/3);  d_j = p - 3q - 1;  p = q
                    acc += x[:, j::5] @ d_j

peels one trit plane per iteration with float arithmetic (exact for values
< 243) and never materializes the interleaved (K, N) weight matrix.  On
XLA-CPU this is ~2-2.5x faster than decode-then-matmul at decode shapes
(M<=8) — the margin that flips `benchmarks/baseline.json` to fused < dense.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import das as das_lib
from repro.core import twd

__all__ = [
    "f32dec_matmul", "plain_matmul", "decode_matmul", "scatter_dense",
    "masked_dense", "XLA_GEMM_IMPLS",
]

TRITS_PER_BYTE = twd.TRITS_PER_BYTE

# dense decode-GEMM implementations selectable by the autotuner; the
# "xla_dense_*" aliases are the same GEMMs fed DAS-mask-densified activations
XLA_GEMM_IMPLS = ("xla_f32dec", "xla_plain", "xla_dense_f32dec",
                  "xla_dense_plain")


def f32dec_matmul(x: jax.Array, packed: jax.Array, w_scale: jax.Array,
                  x_scale: jax.Array | None = None) -> jax.Array:
    """(M, K) f32 @ dequant(packed[:K/5]) via the strided 5-way split.

    Requires K % 5 == 0; export row padding beyond K/5 is sliced off.
    """
    m, k = x.shape
    if k % TRITS_PER_BYTE:
        raise ValueError(f"f32dec_matmul needs K % 5 == 0, got K={k}")
    pf = packed[: k // TRITS_PER_BYTE].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    acc = None
    for j in range(TRITS_PER_BYTE):
        q = jnp.floor(pf / 3.0)
        dj = pf - 3.0 * q - 1.0          # trit plane j in {-1, 0, +1}
        pf = q
        t = xf[:, j::TRITS_PER_BYTE] @ dj
        acc = t if acc is None else acc + t
    y = acc * jnp.asarray(w_scale, jnp.float32)
    if x_scale is not None:
        y = y * x_scale
    return y


def plain_matmul(x: jax.Array, packed: jax.Array, w_scale: jax.Array,
                 x_scale: jax.Array | None = None) -> jax.Array:
    """Decode-then-matmul on the arithmetic unpack (any K, incl. K % 5 != 0)."""
    m, k = x.shape
    w = twd.unpack_ternary_arith(packed, k).astype(jnp.float32)
    y = (x.astype(jnp.float32) @ w) * jnp.asarray(w_scale, jnp.float32)
    if x_scale is not None:
        y = y * x_scale
    return y


def decode_matmul(x: jax.Array, packed: jax.Array, w_scale: jax.Array, *,
                  impl: str, x_scale: jax.Array | None = None) -> jax.Array:
    """Dispatch one of XLA_GEMM_IMPLS on dense (already masked) activations."""
    if impl.endswith("f32dec"):
        return f32dec_matmul(x, packed, w_scale, x_scale)
    if impl.endswith("plain"):
        return plain_matmul(x, packed, w_scale, x_scale)
    raise ValueError(f"decode_matmul: unknown impl {impl!r}")


def scatter_dense(values: jax.Array, indices: jax.Array, k: int, *,
                  keep: int, block: int) -> jax.Array:
    """Compacted (M, Kc) values/abs-indices -> dense-masked (M, K).

    The XLA-CPU form of the butterfly router's inverse: a block-local
    compare-select (gathers are catastrophically slow on this backend).
    Exactly equals x * das_mask(x) for das_compact output.
    """
    m, kc = values.shape
    nb = k // block
    vals = values.reshape(m, nb, keep)
    loc = indices.reshape(m, nb, keep) % block
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, 1, keep, block), 3)
    hit = loc[..., None] == lanes
    dense = jnp.sum(jnp.where(hit, vals[..., None].astype(jnp.float32), 0.0),
                    axis=2)
    return dense.reshape(m, k)


def masked_dense(x: jax.Array, *, keep: int, block: int) -> jax.Array:
    """Dense DAS-masked activations via the rank-compare mask (no top-k sort).

    The shared per-token prep of the tuned CPU path: one mask feeds every
    sibling projection, and das_mask handles non-block-divisible K with a
    dense tail (bitnet d_ff=5460).
    """
    mask = das_lib.das_mask(x, block_size=block, keep=keep)
    return (x * mask.astype(x.dtype)).astype(jnp.float32)
