"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the bit-faithful specification its kernel is tested against
(tests/test_kernels_*.py sweep shapes & dtypes with assert_allclose).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import twd
from repro.core.lpsa import lpsa_allowed

__all__ = [
    "twd_decode_ref",
    "ternary_gemm_ref",
    "ternary_gemm_packed_ref",
    "das_topk_mask_ref",
    "das_gemv_ref",
    "das_ternary_gemm_ref",
    "sparse_attn_ref",
]


def twd_decode_ref(packed: jax.Array, k: int) -> jax.Array:
    """uint8 base-3 packed (Kp, N) -> int8 trits (k, N)."""
    return twd.unpack_ternary(packed, k)


def ternary_gemm_ref(x: jax.Array, w_trits: jax.Array, w_scale: jax.Array,
                     x_scale: jax.Array | None = None) -> jax.Array:
    """f32 = (x int8/float (M,K)) @ (trits (K,N)) * w_scale [* x_scale rows].

    Accumulation in int32 when x is int8 (exact), f32 otherwise.
    """
    if x.dtype == jnp.int8:
        acc = jax.lax.dot_general(
            x.astype(jnp.int32), w_trits.astype(jnp.int32),
            (((1,), (0,)), ((), ())))
        out = acc.astype(jnp.float32) * w_scale
        if x_scale is not None:
            out = out * x_scale
        return out
    out = jnp.dot(x.astype(jnp.float32), w_trits.astype(jnp.float32)) * w_scale
    if x_scale is not None:
        out = out * x_scale
    return out


def ternary_gemm_packed_ref(x: jax.Array, packed: jax.Array, w_scale: jax.Array,
                            k: int, x_scale: jax.Array | None = None) -> jax.Array:
    """Fused TWD-decode + ternary GEMM oracle."""
    w = twd_decode_ref(packed, k)
    return ternary_gemm_ref(x, w, w_scale, x_scale)


def das_topk_mask_ref(x: jax.Array, *, block_size: int, keep: int) -> jax.Array:
    """Rank-based Top-K-per-block mask (== core.das.das_mask semantics).

    keep lane i  <=>  #{ |x_j| > |x_i| } + #{ j<i : |x_j| == |x_i| }  <  keep.
    The O(B^2) compare form is what the kernel vectorizes (B = 32).
    """
    kdim = x.shape[-1]
    nb = kdim // block_size
    a = jnp.abs(x).reshape(x.shape[:-1] + (nb, block_size))
    gt = (a[..., None, :] > a[..., :, None]).sum(-1)          # strictly greater
    lane = jnp.arange(block_size)
    eq_before = ((a[..., None, :] == a[..., :, None])
                 & (lane[None, :] < lane[:, None])).sum(-1)
    rank = gt + eq_before
    return (rank < keep).reshape(x.shape)


def das_gemv_ref(values: jax.Array, indices: jax.Array, w_trits: jax.Array,
                 w_scale: jax.Array) -> jax.Array:
    """Compacted sparse GEMV oracle: gather kept weight rows, dense dot.

    values/indices: (Kc,) — block-compacted activation (core.das.das_compact);
    w_trits: (K, N) int8.  Returns (N,) f32.
    """
    rows = jnp.take(w_trits, indices, axis=0).astype(jnp.float32)  # (Kc, N)
    return (values.astype(jnp.float32) @ rows) * w_scale


def das_ternary_gemm_ref(values: jax.Array, indices: jax.Array,
                         packed: jax.Array, w_scale: jax.Array,
                         k: int) -> jax.Array:
    """Fused DAS + TWD oracle: decode packed weights, gather kept rows per
    batch row, dense dot.

    values/indices: (M, Kc) block-compacted activations (core.das.das_compact);
    packed: (K/5, N) uint8 base-3.  Returns (M, N) f32.
    """
    w = twd_decode_ref(packed, k).astype(jnp.float32)       # (K, N)
    rows = jnp.take(w, indices, axis=0)                     # (M, Kc, N)
    return jnp.einsum("mk,mkn->mn", values.astype(jnp.float32),
                      rows) * w_scale


def sparse_attn_ref(q, k, v, q_pos, k_pos, *, sink: int, window: int,
                    softcap: float | None = None) -> jax.Array:
    """Single-head sink+window attention oracle.

    q: (Lq, D); k, v: (Lk, D); q_pos (Lq,), k_pos (Lk,) absolute positions
    (k_pos < 0 marks an invalid/empty slot).  f32 softmax.
    """
    d = q.shape[-1]
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) / jnp.sqrt(
        jnp.float32(d))
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    mask = lpsa_allowed(q_pos[:, None], k_pos[None, :], sink, window)
    mask = mask & (k_pos >= 0)[None, :]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(-1, keepdims=True), p, 0.0)
    return (p @ v.astype(jnp.float32)).astype(q.dtype)
