"""DSE-driven kernel autotuner: perfmodel ranking + timed confirmation.

The repo's DSE machinery (`core/dse.py`, `core/perfmodel.py`) explored the
TENET design space analytically but never fed the kernels.  This module
closes that loop for serving: per (op, backend, shape) it

  1. enumerates candidate configs — Pallas tile shapes (block_m/n/k) where
     the backend can compile them, XLA-native decode-GEMM implementations
     (kernels/xla_gemm.py) on CPU/GPU, chunked-flash kv-chunk sizes for
     attention;
  2. ranks them with :func:`repro.core.perfmodel.kernel_cost` (roofline);
  3. confirms the top ``budget`` candidates with real timed runs on random
     operands; and
  4. persists the winner to an on-disk JSON cache keyed by shape+backend.

Tuning must happen EAGERLY (``tune``), before jit tracing: ``ServeEngine``
warms up its decode/prefill shapes at construction, and a populated cache
makes later warmups free (zero timed runs — asserted in tests).  Inside a
trace, dispatch goes through ``lookup`` — a pure cache read that falls back
to the perfmodel's top-ranked candidate on a miss, never timing anything.
Note jit caches bake the config chosen at trace time: re-tune (or delete
the cache file) *before* building engines, not after.

Cache location: ``$TENET_AUTOTUNE_CACHE`` if set, else
``~/.cache/tenet-repro/autotune-<backend>.json``.  Format: one JSON object
``{"version": 1, "entries": {key: {impl, block_m, block_n, block_k, us}}}``
with keys like ``das_ternary_gemm|cpu|block32|k1280|keep16|m4|n512``.

CLI (bounded mode, exercised by CI):
    PYTHONPATH=src python -m repro.kernels.autotune \
        --backend interpret --budget 2 --cache .autotune/ci.json
"""

from __future__ import annotations

import argparse
import json
import os
import time
from dataclasses import asdict, dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import das as das_lib
from repro.core import perfmodel, twd
from repro.kernels import ref, xla_gemm
from repro.kernels.das_gemm import das_ternary_gemm as _das_gemm_pallas
from repro.kernels.sparse_attn import sparse_attention as _sparse_attn_pallas
from repro.kernels.ternary_gemm import (K_SLAB, TRITS_PER_BYTE,
                                        ternary_gemm as _ternary_gemm_pallas)

__all__ = [
    "TileConfig", "AutotuneCache", "default_cache", "reset_default_cache",
    "default_cache_path", "shape_key", "attn_dims", "candidates", "tune",
    "lookup", "run_gemm", "run_das_gemm", "main",
]

TUNED_OPS = ("ternary_gemm", "das_ternary_gemm", "sparse_attn")


@dataclass(frozen=True)
class TileConfig:
    """One candidate kernel configuration.

    ``impl``: "pallas" | "interpret" (tiled kernels), one of
    ``xla_gemm.XLA_GEMM_IMPLS`` / "xla_gather" (XLA decode-GEMMs),
    "xla_flash" (chunked attention; ``block_k`` = kv chunk), or "ref".
    ``block_*`` are tile shapes (0 = kernel default).
    """
    impl: str
    block_m: int = 0
    block_n: int = 0
    block_k: int = 0


def default_cache_path(backend: str | None = None) -> str:
    env = os.environ.get("TENET_AUTOTUNE_CACHE")
    if env:
        return env
    backend = backend or jax.default_backend()
    return os.path.join(os.path.expanduser("~"), ".cache", "tenet-repro",
                        f"autotune-{backend}.json")


class AutotuneCache:
    """On-disk shape+backend -> TileConfig map with write-through persist.

    ``timed_runs`` counts real timed candidate executions over this object's
    lifetime — a populated cache keeps it at zero (the "second warmup does
    no re-timing" property tests assert).
    """

    def __init__(self, path: str | None = None):
        self.path = path or default_cache_path()
        self.entries: dict[str, dict] = {}
        self.timed_runs = 0
        self._load()

    def _load(self) -> None:
        try:
            with open(self.path) as f:
                payload = json.load(f)
            if payload.get("version") == 1:
                self.entries = payload.get("entries", {})
        except (OSError, ValueError):
            self.entries = {}

    def save(self) -> None:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(self.path, "w") as f:
            json.dump({"version": 1, "entries": self.entries}, f, indent=1,
                      sort_keys=True)

    def get(self, key: str) -> TileConfig | None:
        e = self.entries.get(key)
        if e is None:
            return None
        return TileConfig(e["impl"], e.get("block_m", 0), e.get("block_n", 0),
                          e.get("block_k", 0))

    def put(self, key: str, cfg: TileConfig, us: float) -> None:
        self.entries[key] = {**asdict(cfg), "us": round(float(us), 2)}
        self.save()


_DEFAULT: AutotuneCache | None = None


def default_cache() -> AutotuneCache:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = AutotuneCache()
    return _DEFAULT


def reset_default_cache() -> None:
    """Drop the process-wide cache object (re-reads env var + disk)."""
    global _DEFAULT
    _DEFAULT = None


def shape_key(op: str, backend: str, **dims) -> str:
    return "|".join([op, backend] + [f"{k}{v}" for k, v in
                                     sorted(dims.items())])


def attn_dims(*, hq: int, hkv: int, lq: int, lk: int, d: int, sink: int,
              window: int) -> dict:
    """Canonical `sparse_attn` cache dims.  sink/window are clamped to the
    cache length so the full-causal sentinel (sink = 2**30) keys stay sane
    and masks that behave identically share one entry.  Use this on BOTH
    sides (warmup tune + trace-time lookup) so keys always match."""
    return dict(hq=hq, hkv=hkv, lq=lq, lk=lk, d=d,
                sink=min(sink, lk), window=min(window, lk))


# ---------------------------------------------------------------------------
# candidate enumeration
# ---------------------------------------------------------------------------

def candidates(op: str, backend: str, **dims) -> list[TileConfig]:
    """Feasible configs for `op` on `backend` at the given dims.

    ``backend="interpret"`` enumerates only Pallas tile configs run under
    ``interpret=True`` — the bounded CI mode that exercises the tuning
    machinery on runners without a Pallas-compiling backend.
    """
    if op == "sparse_attn":
        return _attn_candidates(backend, **dims)
    if op not in ("ternary_gemm", "das_ternary_gemm"):
        raise ValueError(f"candidates: unknown op {op!r}")
    m, k, n = dims["m"], dims["k"], dims["n"]
    keep, block = dims.get("keep", 0), dims.get("block", 0)
    das = keep > 0
    out: list[TileConfig] = []
    slab_ok = k % K_SLAB == 0 and (not das or (K_SLAB % block == 0
                                               and keep <= block))
    if backend in ("tpu", "gpu", "interpret") and slab_ok:
        impl = "interpret" if backend == "interpret" else "pallas"
        n_slab = k // K_SLAB
        bks = [b for b in (1, 2, 4) if n_slab % b == 0] or [1]
        bms = sorted({min(bm, m) for bm in ((8, 32) if das else (32, 128))})
        bns = sorted({min(bn, n) for bn in (128, 256, 512)})
        out += [TileConfig(impl, bm, bn, bk)
                for bm in bms for bn in bns for bk in bks]
    if backend != "interpret":
        f32_ok = k % TRITS_PER_BYTE == 0
        if das:
            if f32_ok:
                out.append(TileConfig("xla_dense_f32dec"))
            out.append(TileConfig("xla_dense_plain"))
            if k % block == 0:
                out.append(TileConfig("xla_gather"))
        else:
            if f32_ok:
                out.append(TileConfig("xla_f32dec"))
            out.append(TileConfig("xla_plain"))
    return list(dict.fromkeys(out))


def _attn_candidates(backend: str, *, hq, hkv, lq, lk, d,
                     sink=0, window=0) -> list[TileConfig]:
    out: list[TileConfig] = []
    if backend in ("tpu", "gpu", "interpret"):
        impl = "interpret" if backend == "interpret" else "pallas"
        bq = min(128, lq)
        out += [TileConfig(impl, block_m=bq, block_k=bk)
                for bk in sorted({min(b, lk) for b in (64, 128, 256)})]
    if backend != "interpret":
        out += [TileConfig("xla_flash", block_k=c)
                for c in sorted({min(c, lk) for c in (128, 256, 512, lk)})]
    return list(dict.fromkeys(out))


def _model_cost(hw, op: str, cfg: TileConfig, dims: dict) -> float:
    kd = {k: v for k, v in dims.items() if k not in ("sink", "window")}
    return perfmodel.kernel_cost(
        hw, op, cfg.impl, block_m=cfg.block_m, block_n=cfg.block_n,
        block_k=cfg.block_k, **kd)


# ---------------------------------------------------------------------------
# config executors (shared by tuned dispatch and timed confirmation)
# ---------------------------------------------------------------------------

def run_gemm(x, packed, w_scale, x_scale=None, *, cfg: TileConfig | None = None,
             **kw):
    """Dense ternary GEMM under a tuned (or given) config."""
    m, k = x.shape
    if cfg is None:
        cfg = lookup("ternary_gemm", m=m, k=k, n=packed.shape[1],
                     keep=0, block=0)
    if cfg.impl in ("pallas", "interpret"):
        return _ternary_gemm_pallas(
            x, packed, w_scale, x_scale, block_m=cfg.block_m or 128,
            block_n=cfg.block_n or 256, block_k=cfg.block_k or 1,
            interpret=(cfg.impl == "interpret"), **kw)
    if cfg.impl in xla_gemm.XLA_GEMM_IMPLS:
        return xla_gemm.decode_matmul(x, packed, w_scale, impl=cfg.impl,
                                      x_scale=x_scale)
    return ref.ternary_gemm_packed_ref(x, packed, w_scale, k, x_scale)


def run_das_gemm(values, indices, packed, w_scale, *, keep: int, block: int,
                 cfg: TileConfig | None = None, **kw):
    """Fused DAS->ternary GEMM from compacted activations under a config."""
    m, kc = values.shape
    k = kc * block // keep
    if cfg is None:
        cfg = lookup("das_ternary_gemm", m=m, k=k, n=packed.shape[1],
                     keep=keep, block=block)
    if cfg.impl in ("pallas", "interpret"):
        return _das_gemm_pallas(
            values, indices, packed, w_scale, keep=keep, block=block,
            block_m=cfg.block_m or 8, block_n=cfg.block_n or 256,
            block_k=cfg.block_k or 1, interpret=(cfg.impl == "interpret"),
            **kw)
    if cfg.impl.startswith("xla_dense"):
        dense = xla_gemm.scatter_dense(values, indices, k, keep=keep,
                                       block=block)
        return xla_gemm.decode_matmul(dense, packed, w_scale, impl=cfg.impl)
    return ref.das_ternary_gemm_ref(values, indices, packed, w_scale, k)


# ---------------------------------------------------------------------------
# tune / lookup
# ---------------------------------------------------------------------------

def _median_us(fn, *args, iters: int, warmup: int) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def _time_gemm(op: str, cfg: TileConfig, dims: dict, *, iters, warmup) -> float:
    m, k, n = dims["m"], dims["k"], dims["n"]
    keep, block = dims.get("keep", 0), dims.get("block", 0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    trits = rng.integers(-1, 2, size=(k, n)).astype(np.int8)
    packed = jnp.asarray(twd.pack_ternary(trits))    # no pad: kp*5 == k
    scale = jnp.float32(0.5)
    if op == "das_ternary_gemm":
        needs_ca = cfg.impl in ("pallas", "interpret", "xla_gather", "ref")

        def fn(xv, p):
            # time prep + GEMM end-to-end: prep cost differs per impl
            if needs_ca:
                ca = das_lib.das_compact(xv, block_size=block, keep=keep)
                return run_das_gemm(ca.values, ca.indices, p, scale,
                                    keep=keep, block=block, cfg=cfg)
            xs = xla_gemm.masked_dense(xv, keep=keep, block=block)
            return xla_gemm.decode_matmul(xs, p, scale, impl=cfg.impl)
    else:
        def fn(xv, p):
            return run_gemm(xv, p, scale, cfg=cfg)
    return _median_us(jax.jit(fn), x, packed, iters=iters, warmup=warmup)


def _time_attn(cfg: TileConfig, dims: dict, *, iters, warmup) -> float:
    hq, hkv, lq, lk, d = (dims[x] for x in ("hq", "hkv", "lq", "lk", "d"))
    sink, window = dims.get("sink", 0), dims.get("window", lk)
    rng = np.random.default_rng(0)
    q_pos = jnp.arange(lk - lq, lk, dtype=jnp.int32)
    k_pos = jnp.arange(lk, dtype=jnp.int32)
    if cfg.impl == "xla_flash":
        from repro.models.attention import flash_masked  # lazy: no cycle
        q = jnp.asarray(rng.standard_normal((1, lq, hq, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, lk, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, lk, hkv, d)), jnp.float32)
        fn = jax.jit(lambda a, b, c: flash_masked(
            a, b, c, q_pos, k_pos, sink=sink, window=window,
            kv_chunk=cfg.block_k or min(512, lk)))
        return _median_us(fn, q, k, v, iters=iters, warmup=warmup)
    q = jnp.asarray(rng.standard_normal((hq, lq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((hkv, lk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((hkv, lk, d)), jnp.float32)
    fn = jax.jit(lambda a, b, c: _sparse_attn_pallas(
        a, b, c, q_pos, k_pos, sink=sink, window=window,
        block_q=cfg.block_m or 128, block_k=cfg.block_k or 128,
        interpret=(cfg.impl == "interpret")))
    return _median_us(fn, q, k, v, iters=iters, warmup=warmup)


def tune(op: str, *, backend: str | None = None,
         cache: AutotuneCache | None = None, budget: int = 3, iters: int = 3,
         warmup: int = 1, **dims) -> TileConfig:
    """Pick (and persist) the best config for one op+shape.

    Cache hit returns immediately with ZERO timed runs.  On a miss the
    perfmodel ranks all candidates and the top ``budget`` are confirmed with
    real timed runs (each bumps ``cache.timed_runs``).  Call eagerly — never
    from inside a jit trace.
    """
    backend = backend or jax.default_backend()
    cache = cache if cache is not None else default_cache()
    key = shape_key(op, backend, **dims)
    hit = cache.get(key)
    if hit is not None:
        return hit
    cands = candidates(op, backend, **dims)
    if not cands:
        cfg = TileConfig("ref")
        cache.put(key, cfg, -1.0)
        return cfg
    hw = perfmodel.backend_hw("cpu" if backend == "interpret" else backend)
    ranked = sorted(cands, key=lambda c: _model_cost(hw, op, c, dims))
    best, best_us = ranked[0], float("inf")
    for cfg in ranked[:max(1, budget)]:
        try:
            if op == "sparse_attn":
                us = _time_attn(cfg, dims, iters=iters, warmup=warmup)
            else:
                us = _time_gemm(op, cfg, dims, iters=iters, warmup=warmup)
        except Exception:            # infeasible candidate: skip, keep tuning
            continue
        cache.timed_runs += 1
        if us < best_us:
            best, best_us = cfg, us
    cache.put(key, best, best_us if best_us < float("inf") else -1.0)
    return best


def lookup(op: str, *, backend: str | None = None,
           cache: AutotuneCache | None = None, **dims) -> TileConfig:
    """Trace-safe config resolution: cache read, else perfmodel top-1.

    Never times, never persists — safe to call while tracing ``tlin_apply``
    / ``attn_decode``.  A miss means the shape wasn't warmed up; the
    perfmodel choice is deterministic, so traces stay reproducible.
    """
    backend = backend or jax.default_backend()
    cache = cache if cache is not None else default_cache()
    hit = cache.get(shape_key(op, backend, **dims))
    if hit is not None:
        return hit
    cands = candidates(op, backend, **dims)
    if not cands:
        return TileConfig("ref")
    hw = perfmodel.backend_hw("cpu" if backend == "interpret" else backend)
    return min(cands, key=lambda c: _model_cost(hw, op, c, dims))


# ---------------------------------------------------------------------------
# CLI: bounded tuning run (CI smoke + manual re-tuning)
# ---------------------------------------------------------------------------

_SMALL_SHAPES = [
    ("das_ternary_gemm", dict(m=2, k=320, n=128, keep=16, block=32)),
    ("das_ternary_gemm", dict(m=4, k=640, n=256, keep=16, block=32)),
    ("ternary_gemm", dict(m=4, k=320, n=128, keep=0, block=0)),
    ("sparse_attn", dict(hq=4, hkv=2, lq=1, lk=64, d=64, sink=4, window=60)),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Bounded autotune run: rank + time candidates for a "
                    "small shape set and persist the winners.")
    ap.add_argument("--backend", default=None,
                    help="tuning backend (default: the JAX backend); "
                         "'interpret' exercises the Pallas tile search in "
                         "emulation on any host")
    ap.add_argument("--budget", type=int, default=2,
                    help="max timed candidates per shape")
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--cache", default=None,
                    help="cache path (default: $TENET_AUTOTUNE_CACHE or "
                         "~/.cache/tenet-repro/autotune-<backend>.json)")
    args = ap.parse_args(argv)

    cache = AutotuneCache(args.cache) if args.cache else default_cache()
    for op, dims in _SMALL_SHAPES:
        t0 = time.perf_counter()
        cfg = tune(op, backend=args.backend, cache=cache, budget=args.budget,
                   iters=args.iters, **dims)
        key = shape_key(op, args.backend or jax.default_backend(), **dims)
        us = cache.entries[key]["us"]
        print(f"{key} -> {cfg.impl} bm={cfg.block_m} bn={cfg.block_n} "
              f"bk={cfg.block_k} ({us:.1f}us, {time.perf_counter()-t0:.1f}s "
              f"to tune)")
    print(f"cache: {cache.path} ({len(cache.entries)} entries, "
          f"{cache.timed_runs} timed runs this invocation)")


if __name__ == "__main__":
    main()
