"""Pallas TPU kernels: TWD decode + fused ternary mpGEMM (STL-core analogue).

Two kernels:

  * ``twd_decode``   — the paper's 64B:80B decompressor: uint8 base-3 bytes
    (5 trits each) expand to int8 {-1,0,1} in VMEM.  The arithmetic div/mod
    decode replaces the dual-port-ROM lookup (cheaper than a 256-gather on
    the VPU; identical output).
  * ``ternary_gemm`` — fused decode + matmul: activations (int8 or float)
    stream through the MXU against weights that *stay base-3 packed in HBM*
    (1.6 bits/weight).  K is tiled in 320-trit slabs = 64 packed bytes —
    literally the paper's 64B:80B block.  Decode happens on the VPU while the
    MXU consumes the previous slab, so the memory win costs no MXU time.

Weight layout: packed (K/5, N) uint8, packing along K (axis 0) so a TP shard
of the N axis never splits a byte.  Accumulation: f32 (exact for int8
activations up to |K| ~ 1e5 — asserted in the wrapper).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TRITS_PER_BYTE = 5
K_SLAB = 320          # trits per K tile  (= 64 packed bytes : 80 int2 bytes)
KP_SLAB = K_SLAB // TRITS_PER_BYTE


def _decode_block(packed_u8: jax.Array) -> jax.Array:
    """(Kp, N) uint8 -> (5*Kp, N) trit values in int32, pack order preserved."""
    p = packed_u8.astype(jnp.int32)
    digits = []
    for _ in range(TRITS_PER_BYTE):
        digits.append(p % 3 - 1)
        p = p // 3
    w = jnp.stack(digits, axis=1)                  # (Kp, 5, N)
    return w.reshape(w.shape[0] * TRITS_PER_BYTE, w.shape[2])


# ---------------------------------------------------------------------------
# twd_decode: standalone decompressor (weight prefetch stage)
# ---------------------------------------------------------------------------

def _twd_decode_kernel(p_ref, out_ref):
    out_ref[...] = _decode_block(p_ref[...]).astype(jnp.int8)


def twd_decode(packed: jax.Array, *, block_n: int = 256,
               interpret: bool = False) -> jax.Array:
    """(Kp, N) uint8 -> (5*Kp, N) int8 trits."""
    kp, n = packed.shape
    bkp = min(kp, 512)
    bn = min(n, block_n)
    if kp % bkp or n % bn:
        raise ValueError(f"packed shape {packed.shape} not tileable by ({bkp},{bn})")
    return pl.pallas_call(
        _twd_decode_kernel,
        grid=(kp // bkp, n // bn),
        in_specs=[pl.BlockSpec((bkp, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bkp * TRITS_PER_BYTE, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((kp * TRITS_PER_BYTE, n), jnp.int8),
        interpret=interpret,
    )(packed)


# ---------------------------------------------------------------------------
# ternary_gemm: fused decode + matmul
# ---------------------------------------------------------------------------

def _ternary_gemm_kernel(x_ref, p_ref, wscale_ref, xscale_ref, out_ref, *,
                         n_k: int, x_int8: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = _decode_block(p_ref[...])                  # (bk, bn) int32
    x = x_ref[...]
    if x_int8:
        acc = jax.lax.dot(x.astype(jnp.int32), w,
                          preferred_element_type=jnp.int32)
        out_ref[...] += acc.astype(jnp.float32)
    else:
        acc = jax.lax.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                          preferred_element_type=jnp.float32)
        out_ref[...] += acc

    @pl.when(k == n_k - 1)
    def _finalize():
        out_ref[...] = out_ref[...] * wscale_ref[0, 0] * xscale_ref[...]


def ternary_gemm(x: jax.Array, packed: jax.Array, w_scale: jax.Array,
                 x_scale: jax.Array | None = None, *, block_m: int = 128,
                 block_n: int = 256, block_k: int = 1,
                 interpret: bool = False) -> jax.Array:
    """Y[f32] = (x ⊙ rowscale) @ dequant(packed) — weights never unpacked in HBM.

    x: (M, K) int8 | bf16 | f32;  packed: (K/5, N) uint8;  w_scale: scalar;
    x_scale: (M, 1) f32 per-row activation scale (int8 path) or None.
    Tile shapes are autotuner parameters: ``block_m``/``block_n`` bound the
    output tile (degraded to divisors of M/N), ``block_k`` is the number of
    320-trit slabs decoded per K step (degraded to a divisor of K/320).
    """
    m, kdim = x.shape
    kp, n = packed.shape
    if kp * TRITS_PER_BYTE != kdim:
        raise ValueError(f"K mismatch: x K={kdim}, packed holds {kp * TRITS_PER_BYTE}")
    if kdim % K_SLAB:
        raise ValueError(f"K={kdim} must be a multiple of the {K_SLAB}-trit slab")
    if kdim > 100_000 and x.dtype == jnp.int8:
        raise ValueError("f32 accumulation no longer exact for int8 at this K")
    bm = min(block_m, m)
    bn = min(block_n, n)
    if m % bm or n % bn:
        raise ValueError(f"(M,N)=({m},{n}) not tileable by ({bm},{bn})")
    n_slab = kdim // K_SLAB
    bk = max(1, min(block_k, n_slab))
    while n_slab % bk:
        bk -= 1
    n_k = n_slab // bk
    if x_scale is None:
        x_scale = jnp.ones((m, 1), jnp.float32)
    w_scale = jnp.asarray(w_scale, jnp.float32).reshape(1, 1)

    kernel = functools.partial(_ternary_gemm_kernel, n_k=n_k,
                               x_int8=(x.dtype == jnp.int8))
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk * K_SLAB), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk * KP_SLAB, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, packed, w_scale, x_scale)
