"""jit'd public wrappers around the Pallas kernels with XLA fallbacks.

Dispatch policy: Pallas on TPU backends, pure-jnp reference elsewhere
(`interpret=True` forces the Pallas path in emulation — used by tests and
CPU benchmarking).  All model code calls through these so the kernel layer
is swappable per backend without touching the models.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .das_gemm import das_gemv as _das_gemv_pallas
from .das_gemm import das_ternary_gemm as _das_ternary_gemm_pallas
from .sparse_attn import sparse_attention as _sparse_attn_pallas
from .ternary_gemm import K_SLAB, TRITS_PER_BYTE
from .ternary_gemm import ternary_gemm as _ternary_gemm_pallas
from .ternary_gemm import twd_decode as _twd_decode_pallas
from .topk_mask import topk_mask as _topk_mask_pallas

__all__ = [
    "use_pallas", "kernel_wanted", "packed_gemm_ok", "fused_das_ok",
    "twd_decode", "ternary_gemm", "das_gemv", "das_ternary_gemm",
    "topk_mask", "sparse_attention", "K_SLAB",
]


def use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def kernel_wanted(mode: str) -> bool:
    """True when `mode` selects a Pallas execution path (compiled or
    emulated) rather than the pure-jnp reference."""
    return mode in ("pallas", "interpret") or (mode == "auto" and use_pallas())


def packed_gemm_ok(k: int, packed_rows: int) -> bool:
    """Shapes admissible for the fused-decode `ternary_gemm` kernel: the
    packed rows must cover K exactly (no export padding beyond K) and K must
    tile by the 320-trit (64-byte) TWD slab."""
    return packed_rows * TRITS_PER_BYTE == k and k % K_SLAB == 0


def fused_das_ok(k: int, packed_rows: int, das) -> bool:
    """Shapes admissible for the fused `das_ternary_gemm` serving path:
    packed-GEMM-compatible AND the DAS block tiles the TWD slab (so a slab
    holds whole blocks and the compacted stream splits per K tile)."""
    return (das is not None and packed_gemm_ok(k, packed_rows)
            and K_SLAB % das.block == 0 and 0 < das.keep <= das.block)


def twd_decode(packed: jax.Array, k: int, *, mode: str = "auto") -> jax.Array:
    """uint8 (Kp, N) -> int8 trits (k, N)."""
    if mode == "pallas" or (mode == "auto" and use_pallas()):
        return _twd_decode_pallas(packed)[:k]
    if mode == "interpret":
        return _twd_decode_pallas(packed, interpret=True)[:k]
    return ref.twd_decode_ref(packed, k)


def ternary_gemm(x: jax.Array, packed: jax.Array, w_scale: jax.Array,
                 x_scale: jax.Array | None = None, *, mode: str = "auto",
                 **kw) -> jax.Array:
    """(M, K) x base-3-packed (K/5, N) -> (M, N) f32."""
    if mode == "pallas" or (mode == "auto" and use_pallas()):
        return _ternary_gemm_pallas(x, packed, w_scale, x_scale, **kw)
    if mode == "interpret":
        return _ternary_gemm_pallas(x, packed, w_scale, x_scale,
                                    interpret=True, **kw)
    k = x.shape[-1]
    return ref.ternary_gemm_packed_ref(x, packed, w_scale, k, x_scale)


def das_gemv(values: jax.Array, indices: jax.Array, w_trits: jax.Array,
             w_scale: jax.Array, *, keep: int, mode: str = "auto",
             **kw) -> jax.Array:
    if mode == "pallas" or (mode == "auto" and use_pallas()):
        return _das_gemv_pallas(values, indices, w_trits, w_scale, keep=keep, **kw)
    if mode == "interpret":
        return _das_gemv_pallas(values, indices, w_trits, w_scale, keep=keep,
                                interpret=True, **kw)
    return ref.das_gemv_ref(values, indices, w_trits, w_scale)


def das_ternary_gemm(values: jax.Array, indices: jax.Array,
                     packed: jax.Array, w_scale: jax.Array, *, keep: int,
                     block: int = 32, mode: str = "auto", **kw) -> jax.Array:
    """Fused serving path: (M, Kc) compacted activations x base-3 packed
    (K/5, N) -> (M, N) f32 — DAS scatter + TWD decode + matmul in one pass."""
    if mode == "pallas" or (mode == "auto" and use_pallas()):
        return _das_ternary_gemm_pallas(values, indices, packed, w_scale,
                                        keep=keep, block=block, **kw)
    if mode == "interpret":
        return _das_ternary_gemm_pallas(values, indices, packed, w_scale,
                                        keep=keep, block=block,
                                        interpret=True, **kw)
    k = packed.shape[0] * TRITS_PER_BYTE
    return ref.das_ternary_gemm_ref(values, indices, packed, w_scale, k)


def topk_mask(x: jax.Array, *, keep: int, block: int = 32,
              mode: str = "auto", **kw) -> jax.Array:
    """(…, K) -> int8 mask; leading dims flattened into rows."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if mode == "pallas" or (mode == "auto" and use_pallas()):
        m = _topk_mask_pallas(x2, keep=keep, block=block, **kw)
    elif mode == "interpret":
        m = _topk_mask_pallas(x2, keep=keep, block=block, interpret=True, **kw)
    else:
        m = ref.das_topk_mask_ref(x2, block_size=block, keep=keep).astype(jnp.int8)
    return m.reshape(*lead, x.shape[-1])


def sparse_attention(q, k, v, q_pos, k_pos, *, sink: int, window: int,
                     softcap: float | None = None, mode: str = "auto",
                     **kw) -> jax.Array:
    """Batched LPSA attention.  q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D);
    q_pos: (B, Lq); k_pos: (B, Lk).  Returns (B, Hq, Lq, D)."""
    if mode == "pallas" or (mode == "auto" and use_pallas()):
        f = partial(_sparse_attn_pallas, sink=sink, window=window,
                    softcap=softcap, **kw)
        return jax.vmap(f)(q, k, v, q_pos, k_pos)
    if mode == "interpret":
        f = partial(_sparse_attn_pallas, sink=sink, window=window,
                    softcap=softcap, interpret=True, **kw)
        return jax.vmap(f)(q, k, v, q_pos, k_pos)

    def one(qb, kb, vb, qp, kp):
        hq, hkv = qb.shape[0], kb.shape[0]
        n_rep = hq // hkv
        def head(h_q, h_kv_arrs):
            kk, vv = h_kv_arrs
            return ref.sparse_attn_ref(h_q, kk, vv, qp, kp, sink=sink,
                                       window=window, softcap=softcap)
        kr = jnp.repeat(kb, n_rep, axis=0)
        vr = jnp.repeat(vb, n_rep, axis=0)
        return jax.vmap(lambda a, b, c: ref.sparse_attn_ref(
            a, b, c, qp, kp, sink=sink, window=window, softcap=softcap))(
                qb, kr, vr)
    return jax.vmap(one)(q, k, v, q_pos, k_pos)
