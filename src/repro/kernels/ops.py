"""jit'd public wrappers around the kernels with backend-aware dispatch.

Kernel modes (``Runtime.kernel_mode`` / ``--kernel-mode``):

  * ``ref``        — pure-jnp reference paths everywhere.
  * ``interpret``  — Pallas kernels under ``interpret=True`` (emulated; slow
    but exercises the real grid/BlockSpec code on any backend).
  * ``pallas``     — compiled Pallas kernels, unconditionally.
  * ``compiled``   — backend-capability probe: compiled Pallas where the
    backend supports it (TPU/GPU), ``interpret=True`` otherwise, so one mode
    runs the same kernel code everywhere.
  * ``tuned``      — per-shape dispatch from the autotune cache
    (kernels/autotune.py): Pallas tile configs on TPU/GPU, the XLA-native
    decode-GEMMs (kernels/xla_gemm.py) on CPU.  Tune eagerly (ServeEngine
    warmup / ``python -m repro.kernels.autotune``) BEFORE tracing: inside a
    jit trace the lookup is cache-read-only and falls back to the perfmodel
    ranking on a miss.
  * ``auto``       — ``pallas`` on TPU, reference elsewhere (legacy default).

All model code calls through these so the kernel layer is swappable per
backend without touching the models.  When a kernel mode is requested but a
shape is inadmissible (``packed_gemm_ok`` / ``fused_das_ok``), the caller
falls back to the reference path and reports it via :func:`note_fallback` —
once per shape (the warning fires at trace time, and XLA traces each shape
once), with counters surfaced in ``ServeEngine`` stats.
"""

from __future__ import annotations

import enum
import warnings
from collections import Counter
from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .das_gemm import das_gemv as _das_gemv_pallas
from .das_gemm import das_ternary_gemm as _das_ternary_gemm_pallas
from .sparse_attn import sparse_attention as _sparse_attn_pallas
from .ternary_gemm import K_SLAB, TRITS_PER_BYTE
from .ternary_gemm import ternary_gemm as _ternary_gemm_pallas
from .ternary_gemm import twd_decode as _twd_decode_pallas
from .topk_mask import topk_mask as _topk_mask_pallas

__all__ = [
    "KernelMode", "KERNEL_MODES",
    "backend_kind", "pallas_compiled_ok", "use_pallas",
    "kernel_wanted", "attn_kernel_wanted", "packed_gemm_ok", "fused_das_ok",
    "note_fallback", "fallback_counts", "reset_fallbacks",
    "twd_decode", "ternary_gemm", "das_gemv", "das_ternary_gemm",
    "topk_mask", "sparse_attention", "K_SLAB",
]

class KernelMode(str, enum.Enum):
    """Typed kernel-mode selector replacing the stringly-typed mode kwarg.

    A ``str`` subclass, so every existing ``mode == "ref"`` /
    ``mode in ("pallas", ...)`` comparison keeps working on members.  Code
    that stores or hashes modes should normalise through
    ``KernelMode.parse(x).value`` (enum members hash by name, not by the
    mixed-in string value, so a raw member is a poor dict key next to
    plain strings).
    """
    REF = "ref"
    INTERPRET = "interpret"
    PALLAS = "pallas"
    COMPILED = "compiled"
    TUNED = "tuned"
    AUTO = "auto"
    # GSPMD-safe serving path: reference-style math with slice-free packed
    # decode, so XLA can shard the contraction over the "model" mesh axis
    # (see models/ternary_linear._apply_packed_sharded).  Excluded from
    # kernel_wanted/attn_kernel_wanted — Pallas kernels stay single-device.
    SHARDED = "sharded"

    def __str__(self) -> str:           # str(KernelMode.REF) == "ref" on 3.10+
        return self.value

    @classmethod
    def parse(cls, value) -> "KernelMode":
        """Accept a member, canonical name, or alias; reject anything else
        with a ValueError that lists the valid modes."""
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            v = _KERNEL_MODE_ALIASES.get(value.strip().lower(),
                                         value.strip().lower())
            try:
                return cls(v)
            except ValueError:
                pass
        raise ValueError(
            f"unknown kernel mode {value!r}: valid modes are "
            f"{', '.join(m.value for m in cls)} (aliases: "
            f"{', '.join(f'{a}->{b}' for a, b in sorted(_KERNEL_MODE_ALIASES.items()))})")


_KERNEL_MODE_ALIASES = {
    "reference": "ref", "jnp": "ref", "xla": "ref",
    "interp": "interpret", "emulate": "interpret", "emulated": "interpret",
    "mosaic": "pallas",
    "autotune": "tuned", "autotuned": "tuned",
    "spmd": "sharded", "gspmd": "sharded",
}

KERNEL_MODES = tuple(m.value for m in KernelMode)


def backend_kind() -> str:
    """The active JAX backend: "cpu" | "gpu" | "tpu"."""
    return jax.default_backend()


def pallas_compiled_ok() -> bool:
    """Can Pallas kernels compile natively on this backend?"""
    return backend_kind() in ("tpu", "gpu")


def use_pallas() -> bool:
    return backend_kind() == "tpu"


def kernel_wanted(mode: str) -> bool:
    """True when `mode` selects a non-reference execution path for the
    ternary linears (Pallas compiled/emulated, or the tuned dispatch)."""
    return mode in ("pallas", "interpret", "compiled", "tuned") \
        or (mode == "auto" and use_pallas())


def attn_kernel_wanted(mode: str) -> bool:
    """True when decode attention should route through the Pallas
    ``sparse_attn`` kernel.  Narrower than :func:`kernel_wanted`:
    ``interpret`` keeps the XLA flash path (emulated attention per decode
    step is pathological) and ``tuned`` picks per-shape in the caller."""
    return mode in ("pallas", "compiled") or (mode == "auto" and use_pallas())


def _pallas_opts(mode: str) -> dict | None:
    """kwargs for a Pallas call under `mode`, or None for the reference."""
    if mode == "pallas":
        return {}
    if mode == "interpret":
        return {"interpret": True}
    if mode == "compiled":
        return {} if pallas_compiled_ok() else {"interpret": True}
    if mode == "auto" and use_pallas():
        return {}
    return None


def packed_gemm_ok(k: int, packed_rows: int) -> bool:
    """Shapes admissible for the fused-decode `ternary_gemm` kernel: the
    packed rows must cover K exactly (no export padding beyond K) and K must
    tile by the 320-trit (64-byte) TWD slab."""
    return packed_rows * TRITS_PER_BYTE == k and k % K_SLAB == 0


def fused_das_ok(k: int, packed_rows: int, das) -> bool:
    """Shapes admissible for the fused `das_ternary_gemm` serving path:
    packed-GEMM-compatible AND the DAS block tiles the TWD slab (so a slab
    holds whole blocks and the compacted stream splits per K tile)."""
    return (das is not None and packed_gemm_ok(k, packed_rows)
            and K_SLAB % das.block == 0 and 0 < das.keep <= das.block)


# ---------------------------------------------------------------------------
# silent-fallback accounting (once-per-shape warnings + counters)
# ---------------------------------------------------------------------------

_fallbacks: Counter = Counter()
_fallback_warned: set = set()


def note_fallback(op: str, key: tuple, reason: str) -> None:
    """Record that a kernel mode fell back to the jnp reference for `key`
    (a hashable shape signature).  Warns once per (op, key); counts every
    occurrence.  Called at trace time from the dispatchers, so a jitted
    serving step reports each distinct shape exactly once."""
    _fallbacks[(op, key)] += 1
    if (op, key) not in _fallback_warned:
        _fallback_warned.add((op, key))
        warnings.warn(
            f"kernel fallback: {op}{key} -> jnp reference ({reason}); "
            f"perf-sensitive paths should use slab-aligned shapes",
            RuntimeWarning, stacklevel=3)


def fallback_counts() -> dict:
    """{(op, shape_key): count} of reference fallbacks since last reset."""
    return dict(_fallbacks)


def reset_fallbacks() -> None:
    _fallbacks.clear()
    _fallback_warned.clear()


# ---------------------------------------------------------------------------
# op wrappers
# ---------------------------------------------------------------------------

def twd_decode(packed: jax.Array, k: int, *, mode: str = "auto") -> jax.Array:
    """uint8 (Kp, N) -> int8 trits (k, N)."""
    opts = _pallas_opts("compiled" if mode == "tuned" else mode)
    if opts is not None:
        return _twd_decode_pallas(packed, **opts)[:k]
    return ref.twd_decode_ref(packed, k)


def ternary_gemm(x: jax.Array, packed: jax.Array, w_scale: jax.Array,
                 x_scale: jax.Array | None = None, *, mode: str = "auto",
                 **kw) -> jax.Array:
    """(M, K) x base-3-packed (K/5, N) -> (M, N) f32."""
    if mode == "tuned":
        from . import autotune
        return autotune.run_gemm(x, packed, w_scale, x_scale=x_scale, **kw)
    opts = _pallas_opts(mode)
    if opts is not None:
        return _ternary_gemm_pallas(x, packed, w_scale, x_scale, **opts, **kw)
    k = x.shape[-1]
    return ref.ternary_gemm_packed_ref(x, packed, w_scale, k, x_scale)


def das_gemv(values: jax.Array, indices: jax.Array, w_trits: jax.Array,
             w_scale: jax.Array, *, keep: int, mode: str = "auto",
             **kw) -> jax.Array:
    opts = _pallas_opts("compiled" if mode == "tuned" else mode)
    if opts is not None:
        return _das_gemv_pallas(values, indices, w_trits, w_scale, keep=keep,
                                **opts, **kw)
    return ref.das_gemv_ref(values, indices, w_trits, w_scale)


def das_ternary_gemm(values: jax.Array, indices: jax.Array,
                     packed: jax.Array, w_scale: jax.Array, *, keep: int,
                     block: int = 32, mode: str = "auto", **kw) -> jax.Array:
    """Fused serving path: (M, Kc) compacted activations x base-3 packed
    (K/5, N) -> (M, N) f32 — DAS scatter + TWD decode + matmul in one pass."""
    if mode == "tuned":
        from . import autotune
        return autotune.run_das_gemm(values, indices, packed, w_scale,
                                     keep=keep, block=block, **kw)
    opts = _pallas_opts(mode)
    if opts is not None:
        return _das_ternary_gemm_pallas(values, indices, packed, w_scale,
                                        keep=keep, block=block, **opts, **kw)
    k = packed.shape[0] * TRITS_PER_BYTE
    return ref.das_ternary_gemm_ref(values, indices, packed, w_scale, k)


def topk_mask(x: jax.Array, *, keep: int, block: int = 32,
              mode: str = "auto", **kw) -> jax.Array:
    """(…, K) -> int8 mask; leading dims flattened into rows."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    opts = _pallas_opts("compiled" if mode == "tuned" else mode)
    if opts is not None:
        m = _topk_mask_pallas(x2, keep=keep, block=block, **opts, **kw)
    else:
        m = ref.das_topk_mask_ref(x2, block_size=block, keep=keep).astype(jnp.int8)
    return m.reshape(*lead, x.shape[-1])


def sparse_attention(q, k, v, q_pos, k_pos, *, sink: int, window: int,
                     softcap: float | None = None, mode: str = "auto",
                     **kw) -> jax.Array:
    """Batched LPSA attention.  q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D);
    q_pos: (B, Lq); k_pos: (B, Lk).  Returns (B, Hq, Lq, D).  Tile kwargs
    (``block_q``/``block_k``) pass through to the Pallas kernel.  ``tuned``
    resolves per-shape in models/attention.py; here it means ``compiled``."""
    opts = _pallas_opts("compiled" if mode == "tuned" else mode)
    if opts is not None:
        f = partial(_sparse_attn_pallas, sink=sink, window=window,
                    softcap=softcap, **opts, **kw)
        return jax.vmap(f)(q, k, v, q_pos, k_pos)

    def one(qb, kb, vb, qp, kp):
        hq, hkv = qb.shape[0], kb.shape[0]
        n_rep = hq // hkv
        kr = jnp.repeat(kb, n_rep, axis=0)
        vr = jnp.repeat(vb, n_rep, axis=0)
        return jax.vmap(lambda a, b, c: ref.sparse_attn_ref(
            a, b, c, qp, kp, sink=sink, window=window, softcap=softcap))(
                qb, kr, vr)
    return jax.vmap(one)(q, k, v, q_pos, k_pos)
