"""Pallas kernel: DAS Top-K-per-block bitmask (the paper's ASM generator).

Rank-per-lane formulation: inside every 32-lane block, lane i survives iff

    #{ |x_j| > |x_i| }  +  #{ j < i : |x_j| == |x_i| }  <  keep

i.e. strict-rank with lane-order tie-breaking — identical semantics to
core.das.das_mask (proved by tests).  The O(B^2)=32x32 broadcast compare per
block is pure VPU work, fully parallel across the (rows x blocks) grid — no
sort, no data-dependent control flow, which is exactly what the TPU vector
unit wants (the SFU of the paper computes the same TopK in hardware).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 32


def _topk_mask_kernel(x_ref, out_ref, *, keep: int, block: int):
    x = jnp.abs(x_ref[...].astype(jnp.float32))    # (bm, bk)
    bm, bk = x.shape
    nb = bk // block
    a = x.reshape(bm, nb, block)
    ai = a[:, :, :, None]                          # lane i
    aj = a[:, :, None, :]                          # lane j
    gt = jnp.sum((aj > ai), axis=-1)
    lane = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)  # i index
    jlt = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1) < lane
    eq_before = jnp.sum((aj == ai) & jlt[None, None], axis=-1)
    rank = gt + eq_before
    out_ref[...] = (rank < keep).reshape(bm, bk).astype(jnp.int8)


def topk_mask(x: jax.Array, *, keep: int = BLOCK // 2, block: int = BLOCK,
              block_m: int = 128, block_k: int = 512,
              interpret: bool = False) -> jax.Array:
    """(M, K) -> int8 {0,1} mask with `keep` survivors per `block` lanes."""
    m, kdim = x.shape
    if kdim % block:
        raise ValueError(f"K={kdim} not divisible by DAS block {block}")
    bm = min(block_m, m)
    bk = min(block_k, kdim)
    if m % bm or kdim % bk or bk % block:
        raise ValueError(f"bad tiling ({bm},{bk}) for ({m},{kdim})")
    kernel = functools.partial(_topk_mask_kernel, keep=keep, block=block)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, kdim // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, kdim), jnp.int8),
        interpret=interpret,
    )(x)
