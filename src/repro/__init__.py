"""repro — TENET (sparsity-aware LUT-centric ternary LLM inference) on TPU.

Layers: core/ (paper's algorithms) -> kernels/ (Pallas) -> models/ (zoo)
-> distributed/ + optim/ + data/ + checkpoint/ (substrate) -> launch/.
"""
__version__ = "0.1.0"
