"""LR schedules: linear warmup + {cosine, WSD}.

WSD (warmup-stable-decay) is MiniCPM's schedule (arXiv:2404.06395) — the
assigned minicpm-2b config trains with it; cosine is the default elsewhere.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_schedule", "wsd_schedule"]


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = peak_lr * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, peak_lr * cos)


def wsd_schedule(step, *, peak_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup -> stable plateau -> short exponential decay tail."""
    s = jnp.asarray(step, jnp.float32)
    decay_steps = max(int(total * decay_frac), 1)
    decay_start = total - decay_steps
    warm = peak_lr * s / max(warmup, 1)
    tail_prog = jnp.clip((s - decay_start) / decay_steps, 0.0, 1.0)
    tail = peak_lr * jnp.power(final_frac, tail_prog)
    out = jnp.where(s < warmup, warm, peak_lr)
    return jnp.where(s > decay_start, tail, out)
