"""Optimizer substrate: AdamW (ZeRO-1 layout), schedules, grad machinery."""
from . import adamw, grad, schedule  # noqa: F401
