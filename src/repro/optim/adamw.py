"""AdamW with global-norm clipping and ZeRO-1-ready state layout.

Functional: (params, grads, state) -> (params, state).  Optimizer moments
take their PartitionSpecs from distributed.sharding.zero1_specs — sharded
along the data axis on top of the parameter sharding, which is ZeRO-1 under
GSPMD (XLA lowers the update to reduce-scatter + sharded-update +
all-gather when the specs demand it).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_step", "global_norm"]


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_step(params: Any, grads: Any, state: AdamWState, *,
               lr: jax.Array | float, b1: float = 0.9, b2: float = 0.95,
               eps: float = 1e-8, weight_decay: float = 0.1,
               clip_norm: float | None = 1.0) -> tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    if clip_norm is not None:
        scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    t = state.step + 1
    b1c = 1.0 - b1 ** t.astype(jnp.float32)
    b2c = 1.0 - b2 ** t.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mh = m2 / b1c
        vh = v2 / b2c
        step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m2, v2

    # flatten to avoid tuple-of-results vs structural-tuple ambiguity
    leaves_p, treedef = jax.tree.flatten(params)
    leaves = [upd(p, g, m, v) for p, g, m, v in
              zip(leaves_p, jax.tree.leaves(grads), jax.tree.leaves(state.m),
                  jax.tree.leaves(state.v))]
    new_p = treedef.unflatten([x[0] for x in leaves])
    new_m = treedef.unflatten([x[1] for x in leaves])
    new_v = treedef.unflatten([x[2] for x in leaves])
    return new_p, AdamWState(step=t, m=new_m, v=new_v), {"grad_norm": gnorm}
