"""Gradient machinery: accumulation and int8-compressed cross-pod exchange.

`accumulate_grads` microbatches one global batch (compute/comm overlap: XLA
overlaps each microbatch's backward collectives with the next microbatch's
forward).  `compressed_crosspod_mean` applies the error-feedback int8
all-reduce from distributed.collectives across the "pod" axis only — the
DCN hop is the thin pipe; ICI reductions stay full-precision.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.collectives import compressed_psum

__all__ = ["accumulate_grads", "compressed_crosspod_mean", "zeros_error"]


def accumulate_grads(loss_fn: Callable, params: Any, batches: Any,
                     n_micro: int) -> tuple[jax.Array, Any, Any]:
    """Mean loss/grads over n_micro microbatches (scan -> O(1) live grads).

    batches: pytree whose leaves have a leading n_micro axis.
    """
    gfn = jax.value_and_grad(loss_fn, has_aux=True)

    def body(acc, mb):
        (loss, _aux), g = gfn(params, mb)
        return jax.tree.map(jnp.add, acc, g), loss

    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    acc, losses = jax.lax.scan(body, zero, batches)
    grads = jax.tree.map(lambda g: g / n_micro, acc)
    return jnp.mean(losses), grads, None


def zeros_error(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_crosspod_mean(grads: Any, error: Any, mesh,
                             pod_axis: str = "pod") -> tuple[Any, Any]:
    """int8 error-feedback mean of per-pod gradients across the pod axis.

    grads must be per-pod partial means (batch sharded per pod, loss averaged
    within pod).  Leaves are exchanged compressed; error feedback carries the
    quantization residual to the next step.  ``mesh`` may be a jax Mesh or a
    ``distributed.plan.Topology`` (built into a mesh here).
    """
    from repro.distributed.plan import Topology
    if isinstance(mesh, Topology):
        mesh = mesh.build_mesh()
    n_pods = mesh.shape[pod_axis]

    def local(g, e):
        def one(gl, el):
            s, e2 = compressed_psum(gl, pod_axis, el)
            return s / n_pods, e2
        flat_g, treedef = jax.tree.flatten(g)
        out = [one(gl, el) for gl, el in zip(flat_g, jax.tree.leaves(e))]
        return (treedef.unflatten([o[0] for o in out]),
                treedef.unflatten([o[1] for o in out]))

    spec = jax.tree.map(lambda _: P(), grads)
    from repro.distributed.sharding import shard_map
    return shard_map(local, mesh=mesh, in_specs=(spec, spec),
                     out_specs=(spec, spec), check_vma=False)(grads, error)
