"""BitNet-3B (paper's own model, Table II) — ternary LLaMA-like."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="bitnet-3b", family="dense",
    n_layers=26, d_model=3200, n_heads=32, n_kv_heads=32, head_dim=100,
    d_ff=8640, vocab=32_000, tie_embeddings=True,
)
