"""Config schema: model architecture + TENET feature flags + run shapes.

One frozen dataclass tree describes every architecture in the zoo; the TENET
techniques (ternary linears, DAS, TWD, LPSA) are first-class switches that
compose with any family.  `reduced()` derives the CPU smoke-test variant of a
config (same family/pattern, tiny dims).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Literal

__all__ = [
    "DasConfig", "LpsaConfig", "TernaryConfig", "MoeConfig", "SsmConfig",
    "ModelConfig", "reduced",
]

Family = Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]
# per-layer mixer kinds used in `layer_pattern`
Mixer = Literal["attn", "local", "mamba", "rwkv", "gla"]


@dataclass(frozen=True)
class DasConfig:
    """Dynamic Activation N:M sparsity (paper Sec. III-C)."""
    block: int = 32
    keep: int = 16            # S_a = keep / block

    @property
    def s_a(self) -> float:
        return self.keep / self.block


@dataclass(frozen=True)
class LpsaConfig:
    """Sink+window sparse attention + pack-fused dataflow (Sec. IV-B)."""
    sink: int = 128
    window: int = 896         # TL_SA = sink + window = 1024 (paper)
    chunk: int = 256          # pack size C

    @property
    def tl_sa(self) -> int:
        return self.sink + self.window


@dataclass(frozen=True)
class TernaryConfig:
    """Ternary linear-layer stack: QAT + serving format (Secs. III-B/E)."""
    enabled: bool = True
    das: DasConfig | None = field(default_factory=DasConfig)
    twd: bool = True                   # serve weights base-3 packed (1.6 b/w)
    serve_format: Literal["packed", "int8", "bf16"] = "packed"


@dataclass(frozen=True)
class MoeConfig:
    n_experts: int = 128
    top_k: int = 8
    d_expert: int = 768
    n_shared: int = 0                  # shared (always-on) experts
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SsmConfig:
    """Mamba2 SSD block dims."""
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256                   # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None        # None => d_model // n_heads
    # repeating per-layer mixer pattern; len(pattern) divides layers or the
    # remainder forms an unrolled tail (e.g. gemma3's 5 local : 1 global).
    layer_pattern: tuple[str, ...] = ("attn",)
    window: int = 4096                 # local-attention window width
    attn_softcap: float | None = None
    logit_softcap: float | None = None
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    act: str = "silu"
    ffn_kind: str = "gated"     # gated (3-mat GLU) | mlp (2-mat)
    # family extensions
    moe: MoeConfig | None = None
    ssm: SsmConfig | None = None
    shared_attn: bool = False          # zamba2: one attn block's weights shared
    frontend: Literal["none", "audio_frames", "vision_patches"] = "none"
    # TENET features
    ternary: TernaryConfig = field(default_factory=TernaryConfig)
    lpsa: LpsaConfig | None = field(default_factory=LpsaConfig)
    # numerics / runtime
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding rows padded to 128 (16-way TP + MXU lane alignment);
        logits beyond `vocab` are masked (the Megatron vocab-pad recipe)."""
        return ((self.vocab + 127) // 128) * 128

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim_

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim_

    @property
    def attention_free(self) -> bool:
        return all(p in ("mamba", "rwkv", "gla") for p in self.layer_pattern)

    def layer_kinds(self) -> tuple[str, ...]:
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, kinds = self.d_model, self.layer_kinds()
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for kind in kinds:
            if kind in ("attn", "local"):
                total += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            elif kind == "mamba":
                s = self.ssm or SsmConfig()
                di = s.expand * d
                total += d * (2 * di + 2 * s.state_dim + di // s.head_dim) + di * d
            elif kind in ("rwkv", "gla"):
                total += 5 * d * d
            if self.moe is not None:
                e = self.moe
                total += d * e.n_experts  # router
                total += (e.n_experts + e.n_shared) * 3 * d * e.d_expert
            elif kind != "mamba":  # mamba blocks in zamba/mamba have no sep. FFN
                total += 3 * d * self.d_ff if self.act == "silu" else 2 * d * self.d_ff
        return total


def reduced(cfg: ModelConfig, *, n_layers: int | None = None,
            d_model: int = 64, vocab: int = 512) -> ModelConfig:
    """Smoke-test variant: same family & pattern, tiny dims (CPU-runnable)."""
    pat = len(cfg.layer_pattern)
    nl = n_layers if n_layers is not None else max(pat, 2 if pat == 1 else pat)
    hd = 16
    n_kv = max(1, min(2, cfg.n_kv_heads))
    n_heads = max(n_kv, 4 if cfg.n_heads >= 4 else cfg.n_heads)
    kw: dict = dict(
        name=cfg.name + "-smoke", n_layers=nl, d_model=d_model,
        n_heads=n_heads, n_kv_heads=n_kv, head_dim=hd,
        d_ff=d_model * 2, vocab=vocab, window=32,
        lpsa=None if cfg.lpsa is None else LpsaConfig(sink=8, window=24, chunk=16),
        ternary=replace(cfg.ternary,
                        das=None if cfg.ternary.das is None else DasConfig(32, 16)),
        remat=False, scan_layers=False, dtype="float32",
    )
    if cfg.moe is not None:
        # capacity_factor = E/top_k  =>  capacity == token count: no drops,
        # so forward == prefill+decode exactly in the smoke tests.
        kw["moe"] = MoeConfig(n_experts=8, top_k=2, d_expert=d_model * 2,
                              n_shared=cfg.moe.n_shared and 1,
                              capacity_factor=4.0)
    if cfg.ssm is not None:
        kw["ssm"] = SsmConfig(state_dim=16, head_dim=16, expand=2,
                              conv_width=4, chunk=16)
    return dataclasses.replace(cfg, **kw)
