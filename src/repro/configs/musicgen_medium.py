"""MusicGen-medium [arXiv:2306.05284] — decoder-only over EnCodec tokens.

Backbone only per the brief: the EnCodec frontend is a stub; input_specs()
supplies precomputed frame embeddings. Cross-attention conditioning omitted
(backbone spec lists self-attention dims only) — noted in DESIGN.md.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab=2048, act="gelu", ffn_kind="mlp",
    frontend="audio_frames", tie_embeddings=False,
)
