"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409] — ViT frontend + nemo decoder.

Backbone only per the brief: the Pixtral-ViT is a stub; input_specs()
supplies precomputed patch embeddings interleaved with text embeddings.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=160,
    d_ff=14_336, vocab=131_072, rope_theta=1_000_000.0,
    frontend="vision_patches", tie_embeddings=False,
)
