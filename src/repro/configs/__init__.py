"""Architecture config registry: `get_config("<arch-id>")` / `--arch <id>`."""

from __future__ import annotations

from importlib import import_module

from .base import ModelConfig, reduced  # noqa: F401
from .shapes import SHAPES, ShapeSpec, shape_by_name  # noqa: F401

# the 10 assigned architectures + the paper's own models
ARCH_MODULES = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "zamba2-2.7b": "zamba2_2p7b",
    "musicgen-medium": "musicgen_medium",
    "gemma2-2b": "gemma2_2b",
    "minicpm-2b": "minicpm_2b",
    "gemma3-1b": "gemma3_1b",
    "stablelm-1.6b": "stablelm_1p6b",
    "pixtral-12b": "pixtral_12b",
    "rwkv6-3b": "rwkv6_3b",
    "bitnet-3b": "bitnet_3b",
    "bitnet-1.3b": "bitnet_1p3b",
    "gla-1.3b": "gla_1p3b",
}
ASSIGNED = tuple(list(ARCH_MODULES)[:10])
PAPER_OWN = tuple(list(ARCH_MODULES)[10:])


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(ARCH_MODULES)}")
    return import_module(f"repro.configs.{ARCH_MODULES[arch]}").CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_MODULES}
