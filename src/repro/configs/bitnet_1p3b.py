"""BitNet-1.3B (paper's own model, Table II)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="bitnet-1.3b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=5460, vocab=32_000, tie_embeddings=True,
)
