"""GLA-1.3B (paper Sec. V-D / Table III) — gated linear attention + TQ + DAS."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gla-1.3b", family="ssm",
    n_layers=24, d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
    d_ff=5632, vocab=32_000,
    layer_pattern=("gla",), lpsa=None, tie_embeddings=False,
)
