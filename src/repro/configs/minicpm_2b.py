"""MiniCPM-2B [arXiv:2404.06395] — llama-like dense; trained with WSD."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, head_dim=64,
    d_ff=5760, vocab=122_753, tie_embeddings=True,
)
