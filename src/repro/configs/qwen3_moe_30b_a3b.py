"""Qwen3-MoE-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — 128-expert top-8 MoE."""
from .base import ModelConfig, MoeConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=64,
    d_ff=768,  # = expert intermediate dim (all FFNs are MoE)
    vocab=151_936,
    moe=MoeConfig(n_experts=128, top_k=8, d_expert=768, n_shared=0),
    rope_theta=1_000_000.0, tie_embeddings=False,
)
