"""Gemma2-2B [arXiv:2408.00118] — local/global alternating, logit softcaps."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab=256_000,
    layer_pattern=("local", "attn"), window=4096,
    attn_softcap=50.0, logit_softcap=30.0,
    act="gelu", tie_embeddings=True,
)
