"""Zamba2-2.7B [arXiv:2411.15242] — Mamba2 backbone + shared attention blocks.

Pattern: five Mamba2 blocks then one (weight-shared) attention block; the
single attention block's parameters are reused at every attn position
(`shared_attn=True`), matching Zamba's shared-block design.
"""
from .base import ModelConfig, SsmConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10_240, vocab=32_000,
    layer_pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "attn"),
    ssm=SsmConfig(state_dim=64, head_dim=64, expand=2, conv_width=4),
    shared_attn=True, tie_embeddings=True,
)
