"""Assigned input shapes (arch x shape grid for the dry-run / roofline).

LM transformer shapes are (seq_len, global_batch).  ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a seq_len KV cache / state), NOT
``train_step``; ``prefill_*`` lowers the prefill serve path; ``train_*``
lowers ``train_step``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

__all__ = ["ShapeSpec", "SHAPES", "shape_by_name"]

Kind = Literal["train", "prefill", "decode"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: Kind
    seq_len: int
    global_batch: int


SHAPES: tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", "train", 4_096, 256),
    ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    ShapeSpec("decode_32k", "decode", 32_768, 128),
    ShapeSpec("long_500k", "decode", 524_288, 1),
)


def shape_by_name(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; have {[s.name for s in SHAPES]}")
