"""Kimi K2 1T-A32B [arXiv:2501.kimi2] — trillion-param MoE, 384e top-8.

Deviation noted in DESIGN.md: the real K2 has one dense lead layer; here all
61 layers are MoE so the stack scans uniformly (param delta ~0.03%).
"""
from .base import ModelConfig, MoeConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048,  # expert intermediate dim
    vocab=163_840,
    moe=MoeConfig(n_experts=384, top_k=8, d_expert=2048, n_shared=1),
    rope_theta=50_000.0, tie_embeddings=False,
)
