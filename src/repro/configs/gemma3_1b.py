"""Gemma3-1B [hf:google/gemma-3-1b-pt] — 5:1 local:global, 128k context."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262_144,
    layer_pattern=("local", "local", "local", "local", "local", "attn"),
    window=512, rope_theta=1_000_000.0,
    act="gelu", tie_embeddings=True,
)
