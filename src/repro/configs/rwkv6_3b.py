"""RWKV6 (Finch) 3B [arXiv:2404.05892] — attention-free, data-dependent decay.

LPSA is inapplicable (no attention); per-token state is already O(1) — the
paper's own GLA experiment (Sec. V-D) is the template: ternary + DAS apply
to all projections. `lpsa=None` encodes the inapplicability.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=8960, vocab=65_536,
    layer_pattern=("rwkv",), lpsa=None, tie_embeddings=False,
)
