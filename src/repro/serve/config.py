"""ServeConfig: the validated engine configuration object.

Consolidates the kwarg pile ``ServeEngine.__init__`` accreted over PR 1-6
(``max_slots``, ``max_len``, ``top_k``, ``seed``, ``policy``, plus the new
paged-pool knobs) into one frozen dataclass with validated defaults.  The
engine still accepts the loose kwargs through a thin back-compat shim that
emits a DeprecationWarning and folds them into a ServeConfig.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.distributed.plan import Topology
from repro.kernels.ops import KernelMode

__all__ = ["ServeConfig"]

_POLICIES = ("continuous", "wave")
_LAYOUTS = ("auto", "paged")
_SCHEDULERS = ("fifo", "deadline")


@dataclass(frozen=True)
class ServeConfig:
    """Engine configuration.

    layout "auto" keeps the per-slot caches resolved from the model config
    (ring for LPSA/local layers, dense full otherwise); "paged" allocates
    would-be full caches as one shared refcounted page arena per layer with
    per-sequence page tables (kvcache.CacheSpec layout="paged").

    ``num_pages`` 0 auto-sizes the pool to the per-slot worst case
    (max_slots * max_len / page_size + null page) — same capacity as the
    dense layout, but allocated lazily and shared across prompts, so *used*
    memory tracks live tokens.  ``prefix_sharing`` enables the radix-trie
    prompt-prefix index (paged layout only).

    ``kernel_mode`` None inherits the Runtime's mode; anything else is
    normalised through kernels.ops.KernelMode.parse and overrides it.

    ``moe_expert_capacity`` bounds the per-expert token load a decode tick
    may present to a MoE router: admission defers new requests while the
    active-slot count (== worst-case tokens any one expert can receive in a
    tick) would exceed it.  0 = unbounded (the model-side decode path is
    always no-drop; this knob only throttles admission).  Ignored for
    dense-FFN configs.

    ``scheduler`` picks the admission order: "fifo" (priority-then-arrival
    with aging) or "deadline" (earliest-effective-deadline-first over
    ``Request.slo_steps``; requests without an SLO get ``slo_default_steps``
    plus an aging penalty per priority level).  ``aging_steps`` is the
    queue wait that decays effective priority by one level (0 = strict
    priority, starvation-prone).  ``preemption`` (deadline scheduler only)
    lets the engine truncate-and-retire the youngest active slot that has
    already blown its OWN deadline when the queue head would otherwise
    miss its SLO — the truncated result is delivered with
    ``preempted=True``.
    """
    max_slots: int = 4
    max_len: int = 512
    layout: str = "auto"
    page_size: int = 16
    num_pages: int = 0
    prefix_sharing: bool = True
    top_k: int = 0
    seed: int = 0
    policy: str = "continuous"
    kernel_mode: str | None = None
    moe_expert_capacity: int = 0
    scheduler: str = "fifo"
    aging_steps: int = 64
    slo_default_steps: int = 256
    preemption: bool = False
    # SPMD serving: a Topology makes the engine build a mesh, resolve a
    # ShardingPlan for params + caches, and jit the decode step with
    # explicit in/out shardings (kernel mode is forced to "sharded", the
    # GSPMD-safe path).  None = single-device, exactly as before.
    topology: Topology | None = None

    def __post_init__(self):
        if self.topology is not None and not isinstance(self.topology,
                                                        Topology):
            raise ValueError(f"topology must be a distributed.plan.Topology "
                             f"or None, got {type(self.topology).__name__}")
        if self.max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {self.max_slots}")
        if self.max_len < 1:
            raise ValueError(f"max_len must be >= 1, got {self.max_len}")
        if self.policy not in _POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}: valid "
                             f"policies are {', '.join(_POLICIES)}")
        if self.layout not in _LAYOUTS:
            raise ValueError(f"unknown layout {self.layout!r}: valid "
                             f"layouts are {', '.join(_LAYOUTS)}")
        if self.layout == "paged":
            if self.page_size < 1:
                raise ValueError(f"page_size must be >= 1, got "
                                 f"{self.page_size}")
            if self.max_len % self.page_size:
                raise ValueError(
                    f"max_len ({self.max_len}) must be a multiple of "
                    f"page_size ({self.page_size}) so logical pages tile "
                    f"the sequence exactly")
            if self.num_pages and self.num_pages < 2:
                raise ValueError("num_pages must be 0 (auto) or >= 2 "
                                 "(page 0 is the reserved null page)")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if self.moe_expert_capacity < 0:
            raise ValueError(f"moe_expert_capacity must be >= 0 "
                             f"(0 = unbounded), got "
                             f"{self.moe_expert_capacity}")
        if self.scheduler not in _SCHEDULERS:
            raise ValueError(f"unknown scheduler {self.scheduler!r}: valid "
                             f"schedulers are {', '.join(_SCHEDULERS)}")
        if self.aging_steps < 0:
            raise ValueError(f"aging_steps must be >= 0 (0 = strict "
                             f"priority), got {self.aging_steps}")
        if self.slo_default_steps < 1:
            raise ValueError(f"slo_default_steps must be >= 1, got "
                             f"{self.slo_default_steps}")
        if self.preemption and self.scheduler != "deadline":
            raise ValueError("preemption requires scheduler='deadline' "
                             "(only deadlines define an over-SLO budget)")
        if self.kernel_mode is not None:
            # normalise via the enum (aliases accepted, unknowns raise)
            object.__setattr__(self, "kernel_mode",
                               KernelMode.parse(self.kernel_mode).value)

    @property
    def pages_per_seq(self) -> int:
        return self.max_len // self.page_size if self.layout == "paged" else 0

    def resolved_num_pages(self) -> int:
        """Pool capacity incl. the null page (auto-sizing when num_pages=0)."""
        if self.layout != "paged":
            return 0
        if self.num_pages:
            return self.num_pages
        return self.max_slots * self.pages_per_seq + 1

    def with_updates(self, **kw) -> "ServeConfig":
        unknown = set(kw) - {f.name for f in dataclasses.fields(self)}
        if unknown:
            raise TypeError(f"unknown ServeConfig field(s): "
                            f"{', '.join(sorted(unknown))}")
        return dataclasses.replace(self, **kw)
