"""Request queue for the continuous-batching engine.

Time is virtual: one unit = one batched decode step of the engine.  Arrival
times in the same units make traces deterministic and replayable (the
benchmarks replay one trace through both the continuous engine and the
lock-step baseline).

Two admission orders are provided:

* ``FifoScheduler`` — priority-then-arrival with **aging**: a request's
  effective priority decays by one level per ``aging_steps`` of queue wait,
  so a saturating stream of high-priority work can no longer starve
  low-priority requests (``aging_steps=0`` restores the old strict order,
  which is documented-starvation-prone).  Because two requests' effective
  priorities cross at a fixed time, aging reduces to the *static* key
  ``priority * aging_steps + arrival`` — a plain heap, no re-keying.

* ``DeadlineScheduler`` — earliest-effective-deadline-first on top of the
  same machinery.  A request with ``slo_steps`` set must finish by
  ``arrival + slo_steps``; requests without an SLO get a default budget
  plus an aging penalty per priority level, so the deadline key itself
  encodes both urgency and the anti-starvation decay.  This is the
  admission order the SLO-aware front door (serve/server.py) uses.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any

__all__ = ["Request", "FifoScheduler", "DeadlineScheduler"]


@dataclass(frozen=True)
class Request:
    """One generation request.

    prompt: (P,) int32 token ids, or (P, D) embeddings for stub-frontend
    families (audio/vlm) — anything `model.prefill` accepts unbatched.
    temperature 0 = greedy; top_k applies only when the engine was built
    with a top-k sampler.  priority: lower runs first (ties by arrival,
    then submission order).  slo_steps: optional deadline — the request
    should finish within this many virtual steps of its arrival; the
    deadline scheduler orders admission by it and the engine can preempt
    over-budget slots to rescue it (ServeConfig.preemption).
    """
    uid: int
    prompt: Any
    max_new_tokens: int
    temperature: float = 0.0
    eos_id: int | None = None
    arrival: int = 0
    priority: int = 0
    slo_steps: int | None = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    def deadline(self, default_slo: int, aging_steps: int) -> int:
        """Effective completion deadline in virtual steps."""
        if self.slo_steps is not None:
            return self.arrival + self.slo_steps
        return self.arrival + default_slo + self.priority * max(aging_steps, 1)


@dataclass
class FifoScheduler:
    """Aged priority-then-arrival FIFO over future-dated requests.

    `pop_ready(now)` only releases requests whose arrival time has passed,
    so a replayed trace admits requests exactly when they "arrive" even
    though the whole trace is submitted up front.  Two heaps: future-dated
    entries wait in an arrival-ordered heap and migrate to the ready heap
    as the clock passes them — amortized O(log N) per request.

    Aging: with ``aging_steps = A > 0`` a request's effective priority at
    time ``now`` is ``priority - (now - arrival) / A``.  Comparing two
    requests, ``p_i - (now - a_i)/A < p_j - (now - a_j)/A`` iff
    ``p_i*A + a_i < p_j*A + a_j`` — time cancels, so the heap key
    ``(priority*A + arrival, priority, arrival)`` implements continuous
    aging without ever re-keying the heap.  A starved low-priority request
    therefore overtakes a fresh high-priority one after waiting
    ``A * (priority gap)`` steps.  ``aging_steps = 0`` keeps the legacy
    strict ``(priority, arrival)`` order (starvation-prone under a
    saturating high-priority stream).
    """
    aging_steps: int = 64
    _future: list = field(default_factory=list)   # (arrival, tie, req)
    _ready: list = field(default_factory=list)    # (rank, tie, req)
    _tie: itertools.count = field(default_factory=itertools.count)
    # O(1) next_arrival: a monotone lower bound on the ready entries'
    # arrivals, maintained at migration time and cleared when the ready
    # heap drains.  Every ready entry's arrival had already passed when it
    # migrated, so the bound (like the exact min) is always <= the current
    # clock — the idle fast-forward `vtime = max(vtime, next_arrival())`
    # behaves identically without rescanning the heap per idle tick.
    _ready_min_arrival: int | None = None

    def _rank(self, req: Request) -> tuple:
        if self.aging_steps:
            return (req.priority * self.aging_steps + req.arrival,
                    req.priority, req.arrival)
        return (req.priority, req.arrival)

    def add(self, req: Request) -> None:
        heapq.heappush(self._future, (req.arrival, next(self._tie), req))

    def _migrate(self, now: int) -> None:
        while self._future and self._future[0][0] <= now:
            arrival, tie, req = heapq.heappop(self._future)
            heapq.heappush(self._ready, (self._rank(req), tie, req))
            if self._ready_min_arrival is None \
                    or arrival < self._ready_min_arrival:
                self._ready_min_arrival = arrival

    def pop_ready(self, now: int) -> Request | None:
        """Best admissible request (arrival <= now), else None.
        Future-dated entries never block admissible ones."""
        self._migrate(now)
        if self._ready:
            req = heapq.heappop(self._ready)[-1]
            if not self._ready:
                self._ready_min_arrival = None
            return req
        return None

    def peek_ready(self, now: int) -> Request | None:
        """Best admissible request without removing it (the engine's
        preemption check inspects the head before deciding to make room)."""
        self._migrate(now)
        return self._ready[0][-1] if self._ready else None

    def next_arrival(self) -> int | None:
        """Earliest arrival among queued requests (for idle fast-forward).

        O(1): when the ready heap is non-empty this returns a lower bound
        on its arrivals (exact until the entry holding the minimum pops);
        since every ready arrival has already passed, any such bound leaves
        `max(vtime, next_arrival())` unchanged — only the future-heap head,
        which is exact, ever moves the clock."""
        cands = []
        if self._ready and self._ready_min_arrival is not None:
            cands.append(self._ready_min_arrival)
        if self._future:
            cands.append(self._future[0][0])
        return min(cands, default=None)

    def __len__(self) -> int:
        return len(self._future) + len(self._ready)

    def __bool__(self) -> bool:
        return bool(self._future or self._ready)


@dataclass
class DeadlineScheduler(FifoScheduler):
    """Earliest-effective-deadline-first admission (EDF).

    Primary key: the request's effective deadline —
    ``arrival + slo_steps`` when an SLO is attached, else
    ``arrival + default_slo + priority * aging_steps`` (the aging term
    keeps low-priority/no-SLO work from starving: its deadline is fixed
    while fresh arrivals keep receiving later ones).  Ties break by raw
    priority then arrival.  The key is static per request, so the heap
    never re-keys; urgency emerges as the clock approaches a deadline
    because newer arrivals carry later deadlines.
    """
    default_slo: int = 256

    def deadline(self, req: Request) -> int:
        return req.deadline(self.default_slo, self.aging_steps)

    def _rank(self, req: Request) -> tuple:
        return (self.deadline(req), req.priority, req.arrival)
