"""Request queue for the continuous-batching engine.

Time is virtual: one unit = one batched decode step of the engine.  Arrival
times in the same units make traces deterministic and replayable (the
benchmarks replay one trace through both the continuous engine and the
lock-step baseline).
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any

__all__ = ["Request", "FifoScheduler"]


@dataclass(frozen=True)
class Request:
    """One generation request.

    prompt: (P,) int32 token ids, or (P, D) embeddings for stub-frontend
    families (audio/vlm) — anything `model.prefill` accepts unbatched.
    temperature 0 = greedy; top_k applies only when the engine was built
    with a top-k sampler.  priority: lower runs first (ties by arrival,
    then submission order).
    """
    uid: int
    prompt: Any
    max_new_tokens: int
    temperature: float = 0.0
    eos_id: int | None = None
    arrival: int = 0
    priority: int = 0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


@dataclass
class FifoScheduler:
    """Priority-then-arrival FIFO over future-dated requests.

    `pop_ready(now)` only releases requests whose arrival time has passed,
    so a replayed trace admits requests exactly when they "arrive" even
    though the whole trace is submitted up front.  Two heaps: future-dated
    entries wait in an arrival-ordered heap and migrate to the
    (priority, arrival)-ordered ready heap as the clock passes them —
    amortized O(log N) per request instead of re-heapifying the whole
    queue on every admission attempt.
    """
    _future: list = field(default_factory=list)   # (arrival, tie, req)
    _ready: list = field(default_factory=list)    # (priority, arrival, tie, req)
    _tie: itertools.count = field(default_factory=itertools.count)

    def add(self, req: Request) -> None:
        heapq.heappush(self._future, (req.arrival, next(self._tie), req))

    def _migrate(self, now: int) -> None:
        while self._future and self._future[0][0] <= now:
            arrival, tie, req = heapq.heappop(self._future)
            heapq.heappush(self._ready, (req.priority, arrival, tie, req))

    def pop_ready(self, now: int) -> Request | None:
        """Best admissible request (arrival <= now) by (priority, arrival),
        else None.  Future-dated entries never block admissible ones."""
        self._migrate(now)
        if self._ready:
            return heapq.heappop(self._ready)[-1]
        return None

    def next_arrival(self) -> int | None:
        """Earliest arrival among queued requests (for idle fast-forward)."""
        cands = [a for _, a, _, _ in self._ready]
        if self._future:
            cands.append(self._future[0][0])
        return min(cands, default=None)

    def __len__(self) -> int:
        return len(self._future) + len(self._ready)

    def __bool__(self) -> bool:
        return bool(self._future or self._ready)
