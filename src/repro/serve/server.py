"""Asyncio HTTP front door over ServeEngine: streaming completions with
SLO-aware admission, backpressure and live telemetry.

Stdlib-only (asyncio streams + a minimal HTTP/1.1 parser — no web
framework dependency).  The engine runs on its own thread inside
``ServeEngine.run_forever``; the event loop never blocks on a jitted
prefill because submissions travel through a thread-safe inbox the engine
thread drains between ticks (the ``poll`` hook), and sampled tokens travel
back via ``loop.call_soon_threadsafe`` into per-request asyncio queues.

Endpoints:

  POST /v1/completions   OpenAI-style completions.  JSON body:
        {"prompt": [ids...] | "text", "max_tokens": N, "temperature": T,
         "stream": bool, "slo_steps": N, "priority": P, "eos_id": id}
      ``prompt`` is canonically a list of int token ids (the models are
      randomly initialized reproductions — there is no tokenizer); a
      string prompt is byte-tokenized (UTF-8 bytes mod vocab) as a
      convenience.  ``stream: true`` returns Server-Sent Events, one
      ``data: {...}`` chunk per sampled token and a final ``data: [DONE]``
      — the OpenAI streaming wire shape with token ids in choice.text.
      Over-capacity submissions get 429 with Retry-After (queue depth >=
      ``max_queue_depth``); malformed / unservable requests get 400.
  GET  /metrics           live Telemetry snapshot (JSON).
  GET  /healthz           liveness + engine vitals.

Request ids (``cmpl-<n>``) map 1:1 onto engine uids from a monotonic
counter; results are popped (``pop_result``) the moment they finish, so
engine-side memory and the uid space stay bounded over an unbounded
request stream — see tests/test_server.py for the soak test.
"""

from __future__ import annotations

import asyncio
import dataclasses
import itertools
import json
import queue as _queue
import threading

import numpy as np

from repro.serve.engine import ServeEngine
from repro.serve.metrics import Telemetry
from repro.serve.scheduler import Request

__all__ = ["ServeHTTPServer"]

_MAX_BODY = 1 << 20


class _HTTPError(Exception):
    def __init__(self, status: int, msg: str, retry_after: int | None = None):
        super().__init__(msg)
        self.status, self.msg, self.retry_after = status, msg, retry_after


_STATUS = {200: "OK", 400: "Bad Request", 404: "Not Found",
           405: "Method Not Allowed", 429: "Too Many Requests",
           500: "Internal Server Error"}


class ServeHTTPServer:
    """One engine, one listener.  ``await start()`` binds the socket and
    spawns the engine thread; ``await stop()`` drains and joins it (clean
    shutdown is test-asserted)."""

    def __init__(self, engine: ServeEngine, host: str = "127.0.0.1",
                 port: int = 8000, *, max_queue_depth: int = 64,
                 default_slo_steps: int | None = None,
                 telemetry: Telemetry | None = None):
        self.engine = engine
        self.host, self.port = host, port
        self.max_queue_depth = max_queue_depth
        self.default_slo_steps = default_slo_steps
        self.telemetry = telemetry or Telemetry(engine=engine)
        if engine.telemetry is None:
            self.telemetry.attach(engine)
        self._uid = itertools.count(1)
        self._streams: dict[int, asyncio.Queue] = {}   # uid -> event queue
        self._inbox: _queue.SimpleQueue = _queue.SimpleQueue()
        self._wake = threading.Event()
        self._stopping = False
        self._loop: asyncio.AbstractEventLoop | None = None
        self._server: asyncio.base_events.Server | None = None
        self._thread: threading.Thread | None = None
        engine.on_token = self._on_token
        engine.on_finish = self._on_finish

    # -- lifecycle --------------------------------------------------------

    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]  # resolve :0
        self._thread = threading.Thread(
            target=self.engine.run_forever,
            kwargs=dict(should_stop=lambda: self._stopping,
                        poll=self._drain_inbox, idle_wait=self._idle_wait),
            name="serve-engine", daemon=True)
        self._thread.start()

    async def stop(self) -> None:
        """Graceful shutdown: stop admitting, let the engine thread exit
        its loop, close the listener."""
        self._stopping = True
        self._wake.set()
        if self._thread is not None:
            await asyncio.get_running_loop().run_in_executor(
                None, self._thread.join, 10.0)
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        self.telemetry.close()

    async def serve_until(self, stop_event: asyncio.Event) -> None:
        await stop_event.wait()
        await self.stop()

    # -- engine-thread side ------------------------------------------------

    def _drain_inbox(self) -> None:
        """run_forever `poll` hook: move queued submissions into the
        engine on the engine thread (arrival stamped at the CURRENT
        vtime, the live-serving meaning of 'arrival')."""
        while True:
            try:
                req = self._inbox.get_nowait()
            except _queue.Empty:
                return
            req = dataclasses.replace(req, arrival=self.engine.vtime)
            try:
                self.engine.submit(req)
            except ValueError as e:   # raced capacity change etc.
                self._post(req.uid, ("error", str(e)))

    def _idle_wait(self) -> bool:
        self._wake.wait(0.05)
        self._wake.clear()
        return not self._stopping

    def _on_token(self, uid: int, tok: int) -> None:
        self._post(uid, ("token", tok))

    def _on_finish(self, result) -> None:
        # claim the result immediately: uids recycle, _results stays bounded
        claimed = self.engine.pop_result(result.uid)
        self._post(result.uid, ("finish", claimed or result))

    def _post(self, uid: int, event) -> None:
        loop = self._loop
        if loop is None or loop.is_closed():
            return
        loop.call_soon_threadsafe(self._dispatch, uid, event)

    def _dispatch(self, uid: int, event) -> None:
        q = self._streams.get(uid)
        if q is not None:
            q.put_nowait(event)

    # -- http plumbing -----------------------------------------------------

    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            try:
                method, path, headers = await self._read_head(reader)
                body = await self._read_body(reader, headers)
                await self._route(method, path, body, writer)
            except _HTTPError as e:
                await self._send_json(writer, e.status,
                                      {"error": {"message": e.msg,
                                                 "code": e.status}},
                                      retry_after=e.retry_after)
            except (asyncio.IncompleteReadError, ConnectionResetError):
                pass
            except Exception as e:   # don't kill the listener
                try:
                    await self._send_json(
                        writer, 500, {"error": {"message": f"{type(e).__name__}: {e}",
                                                "code": 500}})
                except (ConnectionResetError, RuntimeError):
                    pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _read_head(self, reader):
        raw = await reader.readuntil(b"\r\n\r\n")
        head = raw.decode("latin-1").split("\r\n")
        try:
            method, path, _ = head[0].split(" ", 2)
        except ValueError:
            raise _HTTPError(400, "malformed request line")
        headers = {}
        for line in head[1:]:
            if ":" in line:
                k, v = line.split(":", 1)
                headers[k.strip().lower()] = v.strip()
        return method.upper(), path, headers

    async def _read_body(self, reader, headers) -> bytes:
        n = int(headers.get("content-length", 0) or 0)
        if n > _MAX_BODY:
            raise _HTTPError(400, f"body too large ({n} bytes)")
        return await reader.readexactly(n) if n else b""

    async def _route(self, method, path, body, writer) -> None:
        path = path.split("?", 1)[0]
        if path == "/v1/completions":
            if method != "POST":
                raise _HTTPError(405, "POST only")
            await self._completions(body, writer)
        elif path == "/metrics":
            await self._send_json(writer, 200,
                                  self.telemetry.snapshot(self.engine))
        elif path == "/healthz":
            await self._send_json(writer, 200, {
                "ok": True, "vtime": self.engine.vtime,
                "active_slots": self.engine.num_active,
                "queue_depth": self.queue_depth()})
        else:
            raise _HTTPError(404, f"no route for {path}")

    # -- the completions endpoint ------------------------------------------

    def queue_depth(self) -> int:
        return len(self.engine.scheduler) + self._inbox.qsize()

    def _parse_prompt(self, prompt) -> np.ndarray:
        vocab = self.engine.cfg.vocab
        if isinstance(prompt, str):
            if not prompt:
                raise _HTTPError(400, "empty prompt")
            ids = np.frombuffer(prompt.encode("utf-8"),
                                np.uint8).astype(np.int32) % vocab
            return ids
        if isinstance(prompt, list) and prompt and \
                all(isinstance(t, int) for t in prompt):
            ids = np.asarray(prompt, np.int32)
            if (ids < 0).any() or (ids >= vocab).any():
                raise _HTTPError(400, f"token ids must be in [0, {vocab})")
            return ids
        raise _HTTPError(400, "prompt must be a non-empty string or a "
                              "list of int token ids")

    def _build_request(self, payload: dict) -> Request:
        if not isinstance(payload, dict):
            raise _HTTPError(400, "body must be a JSON object")
        prompt = self._parse_prompt(payload.get("prompt"))
        slo = payload.get("slo_steps", self.default_slo_steps)
        try:
            req = Request(
                uid=next(self._uid),
                prompt=prompt,
                max_new_tokens=int(payload.get("max_tokens", 16)),
                temperature=float(payload.get("temperature", 0.0)),
                eos_id=(int(payload["eos_id"])
                        if payload.get("eos_id") is not None else None),
                priority=int(payload.get("priority", 0)),
                slo_steps=int(slo) if slo is not None else None)
        except (TypeError, ValueError) as e:
            raise _HTTPError(400, f"bad request field: {e}")
        try:
            self.engine.validate(req)
        except ValueError as e:
            raise _HTTPError(400, str(e))
        return req

    async def _completions(self, body: bytes, writer) -> None:
        try:
            payload = json.loads(body or b"{}")
        except ValueError:
            raise _HTTPError(400, "body is not valid JSON")
        if self._stopping:
            raise _HTTPError(429, "server shutting down", retry_after=1)
        if self.queue_depth() >= self.max_queue_depth:
            raise _HTTPError(
                429, f"queue depth {self.queue_depth()} at capacity "
                     f"({self.max_queue_depth}); retry later", retry_after=1)
        req = self._build_request(payload)
        stream = bool(payload.get("stream", False))
        q: asyncio.Queue = asyncio.Queue()
        self._streams[req.uid] = q
        try:
            self._inbox.put(req)
            self._wake.set()
            if stream:
                await self._stream_response(req, q, writer)
            else:
                await self._unary_response(req, q, writer)
        finally:
            self._streams.pop(req.uid, None)

    @staticmethod
    def _chunk(req, tokens, finish_reason=None, *, obj="text_completion"):
        return {
            "id": f"cmpl-{req.uid}",
            "object": obj,
            "model": "tenet-repro",
            "choices": [{
                "index": 0,
                "text": " ".join(str(t) for t in tokens),
                "token_ids": [int(t) for t in tokens],
                "finish_reason": finish_reason,
            }],
        }

    async def _next_event(self, q: asyncio.Queue):
        ev = await q.get()
        if ev[0] == "error":
            raise _HTTPError(400, ev[1])
        return ev

    async def _unary_response(self, req, q, writer) -> None:
        while True:
            kind, val = await self._next_event(q)
            if kind == "finish":
                result = val
                break
        out = self._chunk(req, result.tokens.tolist(),
                          "preempted" if result.preempted else "stop")
        out["usage"] = {"prompt_tokens": req.prompt_len,
                        "completion_tokens": int(len(result.tokens)),
                        "ttft_steps": result.ttft_steps,
                        "latency_steps": result.latency_steps,
                        "slo_met": result.slo_met}
        await self._send_json(writer, 200, out)

    async def _stream_response(self, req, q, writer) -> None:
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()
        while True:
            kind, val = await self._next_event(q)
            if kind == "token":
                data = self._chunk(req, [val], None,
                                   obj="text_completion.chunk")
                writer.write(b"data: " + json.dumps(data).encode() + b"\n\n")
                await writer.drain()
            elif kind == "finish":
                result = val
                data = self._chunk(req, [],
                                   "preempted" if result.preempted
                                   else "stop", obj="text_completion.chunk")
                data["usage"] = {"completion_tokens": int(len(result.tokens)),
                                 "ttft_steps": result.ttft_steps,
                                 "latency_steps": result.latency_steps,
                                 "slo_met": result.slo_met}
                writer.write(b"data: " + json.dumps(data).encode() + b"\n\n")
                writer.write(b"data: [DONE]\n\n")
                await writer.drain()
                return

    async def _send_json(self, writer, status: int, obj: dict,
                         retry_after: int | None = None) -> None:
        body = json.dumps(obj).encode()
        head = (f"HTTP/1.1 {status} {_STATUS.get(status, '')}\r\n"
                f"Content-Type: application/json\r\n"
                f"Content-Length: {len(body)}\r\n"
                f"Connection: close\r\n")
        if retry_after is not None:
            head += f"Retry-After: {retry_after}\r\n"
        writer.write(head.encode() + b"\r\n" + body)
        await writer.drain()
