"""Continuous-batching serving engine (slot scheduler + samplers).

The serving counterpart of the paper's low-batch real-time claim: a fixed
``max_slots``-wide jitted decode step (static shapes) whose slots are
admitted, generated, and retired independently — a request can prefill into
a free slot while the other slots keep decoding, because the KV caches
carry per-sequence positions (models/kvcache.py).

Modules:
  scheduler — Request + arrival/priority queues (FifoScheduler with aging,
              DeadlineScheduler: earliest-effective-deadline-first)
  sampler   — greedy / temperature / top-k next-token sampling
  config    — ServeConfig: the validated engine configuration object
  kvpool    — PagePool / RadixIndex: refcounted paged-KV bookkeeping
  engine    — ServeEngine: slot state machine + the jitted decode step
              (run() drains a trace; run_forever() is the always-on
              step-driver the HTTP server owns)
  metrics   — Telemetry: per-request SLO records + rolling live gauges
  server    — ServeHTTPServer: asyncio streaming front door (OpenAI-style
              completions endpoint, backpressure, /metrics)
"""
from repro.serve.config import ServeConfig
from repro.serve.engine import EngineStats, RequestResult, ServeEngine
from repro.serve.kvpool import PagePool, PrefixEntry, RadixIndex
from repro.serve.metrics import Telemetry
from repro.serve.sampler import make_sampler, sample_token
from repro.serve.scheduler import DeadlineScheduler, FifoScheduler, Request

__all__ = ["ServeEngine", "ServeConfig", "EngineStats", "RequestResult",
           "FifoScheduler", "DeadlineScheduler", "Request", "Telemetry",
           "make_sampler", "sample_token",
           "PagePool", "PrefixEntry", "RadixIndex"]
