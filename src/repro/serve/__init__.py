"""Continuous-batching serving engine (slot scheduler + samplers).

The serving counterpart of the paper's low-batch real-time claim: a fixed
``max_slots``-wide jitted decode step (static shapes) whose slots are
admitted, generated, and retired independently — a request can prefill into
a free slot while the other slots keep decoding, because the KV caches
carry per-sequence positions (models/kvcache.py).

Modules:
  scheduler — Request + arrival/priority queue (FifoScheduler)
  sampler   — greedy / temperature / top-k next-token sampling
  engine    — ServeEngine: slot state machine + the jitted decode step
"""
from repro.serve.engine import EngineStats, RequestResult, ServeEngine
from repro.serve.sampler import make_sampler, sample_token
from repro.serve.scheduler import FifoScheduler, Request

__all__ = ["ServeEngine", "EngineStats", "RequestResult",
           "FifoScheduler", "Request", "make_sampler", "sample_token"]
