"""Next-token samplers: greedy / temperature / top-k.

Replaces the hardcoded `argmax` of the old serving drivers.  Sampling is
deterministic per (request uid, token index): the engine derives each
row's PRNG key by folding the request uid and its generated-token counter
into a base key, so a request's tokens do not depend on which other
requests share the decode batch (batch invariance).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sample_token", "make_sampler"]


def sample_token(logits: jax.Array, key: jax.Array, temperature: jax.Array,
                 top_k: int = 0) -> jax.Array:
    """One row: logits (V,) -> token id ().

    temperature <= 0 selects greedy argmax; otherwise softmax sampling at
    `temperature`, restricted to the `top_k` highest logits when top_k > 0
    (static — it shapes the lowered program).
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if top_k and top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1]
        logits = jnp.where(logits >= kth, logits, -jnp.inf)
    scaled = logits / jnp.maximum(temperature, 1e-6)
    drawn = jax.random.categorical(key, scaled).astype(jnp.int32)
    return jnp.where(temperature > 0, drawn, greedy)


def make_sampler(top_k: int = 0):
    """Batched sampler: (logits (B,V), keys (B,), temps (B,)) -> (B,) int32."""
    def sampler(logits, keys, temps):
        return jax.vmap(lambda lg, k, tp: sample_token(lg, k, tp, top_k))(
            logits, keys, temps)
    return sampler
