"""ServeEngine: slot-based continuous batching over per-sequence KV caches.

The engine owns one decode-cache pytree sized for ``max_slots`` sequences
and runs ONE jitted decode step for the whole batch every tick — the
decode step's shapes are static, so it never recompiles as requests come
and go (admission prefill compiles once per pack-aligned prefix length,
a set bounded by max_len / chunk; `reset_clock` lets benchmarks warm
those caches before a timed replay).  Per-slot lifecycle:

  FREE ──admit──> PREFILL ──tail consumed──> DECODE ──eos/max──> FREE

Admission prefills the longest pack-aligned prompt *prefix* through the
LPSA streaming dataflow (batch=1) and writes the resulting layer caches
into the slot's rows; the remaining prompt tail is fed token-by-token
through the shared batched decode step while the other slots keep
generating (token-level admission, Orca-style).  Because every cache row
carries its own position cursor (models/kvcache.attn_write with t: (B,)),
a slot at prompt position 7 coexists with a slot at decode position 900.

Time is virtual: 1 unit == one batched decode step.  Requests carry
arrival times in the same units so traces replay deterministically, and a
request's tokens are bitwise independent of its batch-mates (per-row
attention masks + per-(uid, token) sampling keys) — see
tests/test_serve_engine.py for the batch-invariance check.

``policy="wave"`` degrades the same machinery to lock-step gang
scheduling (admit only when ALL slots are free, barrier until all
finish): the baseline the benchmarks compare against.

Per-slot state is a tagged union over kvcache.CacheSpec layouts, resolved
per layer from ``cfg.layer_kinds()`` (``layout_summary()`` prints it):
full/ring/paged KV for attention layers, O(1) recurrent state for
mamba (ssm carry + chunk-replay buffers), rwkv (wkv + shifts) and gla
(state matrix).  Recurrent-only configs have ``_chunk = 1``: the whole
prompt absorbs through batch-1 prefill (one compile per prompt length)
and decode carries pure state — hybrid stacks mix both in one pytree.
MoE configs decode with no-drop expert capacity (models/moe
decode_capacity); ``ServeConfig.moe_expert_capacity`` optionally bounds
the per-expert tick load via admission control instead of token drops.

``ServeConfig(layout="paged")`` swaps the dense per-slot full caches for a
block-paged KV pool (kvcache.CacheSpec layout="paged"): one refcounted
page arena per full-attention layer, per-slot int32 page tables passed to
the SAME jitted decode step (shapes stay static — the table is data, not
structure), pages allocated lazily as slots cross page boundaries, and a
radix-trie prefix index (serve.kvpool.RadixIndex) that lets admission
reuse the pages + states of the longest cached pack-aligned prompt
prefix instead of re-prefilling it.  Shared pages are copy-on-write: the
first divergent write to a page with refcount > 1 copies it; retiring a
slot releases its references and scrubs pages that drop free.  Used pool
memory therefore tracks live tokens, not ``max_slots * max_len``.
"""

from __future__ import annotations

import threading
import time
import warnings
from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import fault
from repro.distributed.plan import ShardingPlan
from repro.kernels import ops
from repro.models import attention as A
from repro.models import kvcache as KV
from repro.models import model as MD
from repro.models.transformer import Runtime, layer_cache_spec
from repro.serve.config import ServeConfig
from repro.serve.kvpool import PagePool, PrefixEntry, RadixIndex
from repro.serve.sampler import make_sampler, sample_token
from repro.serve.scheduler import DeadlineScheduler, FifoScheduler, Request

__all__ = ["ServeEngine", "ServeConfig", "EngineStats", "RequestResult"]

FREE, PREFILL, DECODE = 0, 1, 2


@dataclass
class RequestResult:
    uid: int
    tokens: np.ndarray            # generated ids (eos included when hit)
    prompt_len: int
    arrival: int                  # vtime units (1 = one batched decode step)
    admit_vtime: int
    first_token_vtime: int
    finish_vtime: int
    admitted_with_active: int = 0  # slots already mid-stream at admission
                                   # (admitted in an earlier tick)
    slo_steps: int | None = None   # deadline budget the request carried
    preempted: bool = False        # truncated by the deadline-rescue hook

    @property
    def latency_steps(self) -> int:
        return self.finish_vtime - self.arrival

    @property
    def ttft_steps(self) -> int:
        return self.first_token_vtime - self.arrival

    @property
    def queue_wait_steps(self) -> int:
        return self.admit_vtime - self.arrival

    @property
    def slo_met(self) -> bool:
        """True when the request finished within its deadline budget (a
        preempted request is truncated, so it never counts as met);
        requests without an SLO vacuously meet it."""
        if self.slo_steps is None:
            return True
        return not self.preempted and self.latency_steps <= self.slo_steps


@dataclass
class EngineStats:
    max_slots: int = 0
    decode_steps: int = 0         # batched step invocations
    active_slot_steps: int = 0    # sum over steps of |active slots|
    generated_tokens: int = 0     # sampled tokens delivered to requests
    prefill_tokens: int = 0       # prompt tokens absorbed via batch-1 prefill
    wall_seconds: float = 0.0
    autotune_timed_runs: int = 0  # timed candidate runs spent in warmup
                                  # (0 when the on-disk cache was already hot)
    kernel_fallbacks: dict = field(default_factory=dict)
                                  # "op(shape)" -> count of silent jnp-ref
                                  # fallbacks observed (kernels/ops counters)
    # paged-pool accounting (zero under the per-slot layout)
    prefix_hits: int = 0          # admissions that reused a cached prefix
    prompt_tokens_reused: int = 0  # prompt tokens absorbed via prefix reuse
    cow_copies: int = 0           # copy-on-write page copies
    prefix_evictions: int = 0     # trie entries evicted to free pages
    pool_peak_pages: int = 0      # peak pages in use during this run
    moe_capacity_deferrals: int = 0  # admissions deferred by the MoE
                                     # expert-capacity bound (ticks a ready
                                     # request waited for a slot to retire)
    preemptions: int = 0          # over-budget slots truncated to rescue a
                                  # deadline-critical queued request
    # elastic recovery (zero unless a WorkerFailure was survived)
    reshards: int = 0             # snapshot -> mesh shrink -> reshard cycles
    recovery_seconds: float = 0.0  # wall time spent rebuilding device state

    @property
    def slot_utilization(self) -> float:
        """Mean fraction of decode-batch rows doing useful work."""
        return self.active_slot_steps / max(1, self.decode_steps
                                            * max(1, self.max_slots))


class _Slot:
    __slots__ = ("state", "req", "input_tok", "input_x", "input_pos",
                 "tail", "tail_idx", "out", "admit_vtime", "first_tok_vtime",
                 "admitted_with_active", "pages", "page_budget")

    def __init__(self):
        self.state = FREE
        self.req = None
        self.pages = None          # paged layout: logical->physical page ids
        self.page_budget = 0       # pages this slot may still allocate


class ServeEngine:
    """Continuous-batching engine over an exported serving-params tree.

    cfg/sparams/rt as elsewhere in the repo; ``max_len`` bounds prompt +
    generation when any layer keeps a full (non-ring) cache.  ``top_k`` is
    static for the jitted step (0 = unrestricted); per-request temperature
    is dynamic.  ``policy``: "continuous" (default) or "wave" (lock-step
    gang-scheduling baseline).  ``kernel_mode`` overrides ``rt.kernel_mode``
    (see kernels/ops.KERNEL_MODES) — with packed weights and DAS enabled
    the kernel modes route decode through the fused ``das_ternary_gemm``
    datapath (compacted activations straight against base-3 packed weights)
    on every slab-aligned layer.

    ``kernel_mode="tuned"`` additionally runs an eager autotune warmup at
    construction: every (op, shape) the jitted decode/prefill steps will
    trace is tuned via kernels/autotune (perfmodel-ranked candidates
    confirmed by timed runs) and persisted to the on-disk cache, so a second
    engine over the same shapes constructs with ZERO timed runs
    (``stats.autotune_timed_runs``).  Re-tune (delete the cache file) after
    changing backends — jit traces bake the config chosen at trace time.
    """

    _LEGACY_KWARGS = ("max_slots", "max_len", "top_k", "seed", "policy",
                      "kernel_mode", "layout", "page_size", "num_pages",
                      "prefix_sharing")

    def __init__(self, cfg: ModelConfig, sparams: dict,
                 rt: Runtime = Runtime(), config: ServeConfig | None = None,
                 **legacy):
        if legacy:
            unknown = set(legacy) - set(self._LEGACY_KWARGS)
            if unknown:
                raise TypeError(f"unknown ServeEngine kwarg(s): "
                                f"{', '.join(sorted(unknown))}")
            warnings.warn(
                "loose ServeEngine kwargs are deprecated; pass "
                "config=ServeConfig(...) (repro.serve.config)",
                DeprecationWarning, stacklevel=2)
            config = (config or ServeConfig()).with_updates(**legacy)
        config = config or ServeConfig()
        if config.kernel_mode is not None:
            rt = replace(rt, kernel_mode=config.kernel_mode)
        else:
            rt = replace(rt,
                         kernel_mode=ops.KernelMode.parse(rt.kernel_mode).value)
        if config.topology is not None and rt.kernel_mode != "sharded":
            if rt.kernel_mode != "ref":
                warnings.warn(
                    f"kernel_mode={rt.kernel_mode!r} is a single-device "
                    f"path; a Topology forces the GSPMD-safe 'sharded' mode",
                    stacklevel=2)
            rt = replace(rt, kernel_mode="sharded")
        self.cfg, self.sparams, self.rt = cfg, sparams, rt
        self.config = config
        max_slots, max_len = config.max_slots, config.max_len
        self.max_slots, self.max_len = max_slots, max_len
        self.policy = config.policy
        if config.scheduler == "deadline":
            self.scheduler = DeadlineScheduler(
                aging_steps=config.aging_steps,
                default_slo=config.slo_default_steps)
        else:
            self.scheduler = FifoScheduler(aging_steps=config.aging_steps)
        self._preempt = config.preemption
        self.stats = EngineStats(max_slots=max_slots)
        self.vtime = 0
        # per-engine baseline of the PROCESS-WIDE kernels/ops fallback
        # counters: stats report deltas vs this snapshot, so two engines in
        # one process never attribute each other's fallbacks
        self._fallback_base: dict = dict(ops.fallback_counts())
        # live-serving hooks (all optional): the HTTP front door streams
        # tokens through on_token/on_finish; a metrics.Telemetry sink
        # attached as .telemetry observes admissions/ticks/finishes
        self.telemetry = None
        self.on_token = None      # callable(uid, token_id) per sampled token
        self.on_finish = None     # callable(RequestResult) at retirement
        # submit/pop_result may be called from another thread than the one
        # driving run_forever (the HTTP server's event loop vs the engine
        # thread); this lock covers the scheduler + result-dict handoffs
        self._lock = threading.RLock()
        self._uses_embeds = MD.uses_embeds(cfg)
        self._cache_dtype = jnp.dtype(cfg.dtype)
        kinds = cfg.layer_kinds()
        sw = [A.kind_sink_window(cfg, k, rt.serve_sparse) for k in kinds
              if k in ("attn", "local")]
        self._has_full = any(s >= A.FULL_SINK for s, _ in sw)
        self._has_stream = any(s < A.FULL_SINK for s, _ in sw)
        # streaming prefill consumes whole packs; prompts prefill their
        # longest pack-aligned prefix and decode the tail token-by-token
        self._chunk = (cfg.lpsa.chunk if cfg.lpsa else 256) \
            if self._has_stream else 1

        # ---- paged pool (layout="paged") --------------------------------
        self._paged = config.layout == "paged"
        self._share = self._paged and config.prefix_sharing \
            and not self._uses_embeds   # embeds have no token ids to key on
        self._page_size = config.page_size
        # only full-attention layers become arenas; a paged engine over a
        # pure ring/recurrent config still shares exact prefix *states*
        # through the trie, just with zero pages per entry
        self._pages_per_seq = config.pages_per_seq if self._has_full else 0
        page_size = self._page_size if self._pages_per_seq else 0
        num_pages = config.resolved_num_pages() if self._pages_per_seq else 0
        self._cache_page_size = page_size
        self._cache_num_pages = num_pages
        # explicit per-layer CacheSpec union: the engine's source of truth
        # for which layers are shared page arenas vs per-slot rows (ring /
        # full / recurrent).  Mirrors the cache pytree structure.
        self._layer_specs = self._build_layer_specs(page_size, num_pages)
        self._paged_stacked = tuple(
            s.layout == "paged" for s in (self._layer_specs["stacked"] or ()))
        self._paged_tail = tuple(
            s.layout == "paged" for s in self._layer_specs["tail"])
        self._rest_is_empty = self._paged and not self._has_non_paged_rows()
        if config.moe_expert_capacity and cfg.moe is None:
            raise ValueError(
                f"moe_expert_capacity={config.moe_expert_capacity} is set "
                f"but config {cfg.name!r} has no MoE layers; drop the bound "
                f"or serve a MoE config")
        self._moe_slot_cap = (config.moe_expert_capacity
                              if cfg.moe is not None else 0)
        self._slots = [_Slot() for _ in range(max_slots)]
        self._results: dict[int, RequestResult] = {}
        self._pending_uids: set[int] = set()
        self._base_key = jax.random.PRNGKey(config.seed)
        self._sampler = make_sampler(config.top_k)
        self._top_k = config.top_k

        # ---- SPMD / elastic-recovery state ------------------------------
        self._topology = config.topology   # live: shrinks on recovery
        self._mesh = None
        self.plan: ShardingPlan | None = None
        self._replays: list[dict] = []     # slot snapshots awaiting re-admit
        # test/ops hook: a fault.FaultInjector checked at each tick top;
        # fault_lost_devices is how many devices a triggered failure costs
        self.fault_injector = None
        self.fault_lost_devices = 1

        if rt.kernel_mode == "tuned":
            self._autotune_warmup()   # eager: must precede any jit trace

        self._build_device_state()

    def _build_device_state(self) -> None:
        """(Re)build everything that lives on devices: the KV pool / radix
        index / page table, the cache pytrees, the mesh + ShardingPlan
        placement of params and caches, and every jitted step.  Called once
        at construction and again by `recover()` after a device loss — the
        jits retrace against the (possibly shrunk) mesh."""
        cfg, rt = self.cfg, self.rt
        max_slots, max_len = self.max_slots, self.max_len
        page_size, num_pages = self._cache_page_size, self._cache_num_pages

        self._pool = PagePool(num_pages, self._page_size) \
            if self._pages_per_seq else None
        self._radix = RadixIndex() if self._share else None
        self._pt = np.zeros((max_slots, max(self._pages_per_seq, 1)),
                            np.int32) if self._paged else None

        self.caches = MD.init_caches(None, cfg, max_slots, max_len, rt,
                                     self._cache_dtype, page_size=page_size,
                                     num_pages=num_pages)
        self._empty1 = MD.init_caches(None, cfg, 1, max_len, rt,
                                      self._cache_dtype)
        # spec-derived flags must agree with the allocated structure
        assert self._paged_stacked == tuple(
            KV.is_paged(c) for c in (self.caches["stacked"] or ()))
        assert self._paged_tail == tuple(
            KV.is_paged(c) for c in self.caches["tail"])
        self._page_bytes = self._compute_page_bytes()

        step_kw: dict = {}
        cache_kw: dict = {}
        if self._topology is not None:
            self._mesh = self._topology.build_mesh()
            plan = ShardingPlan.for_tree(self.sparams, self._topology)
            plan = plan.with_caches(self.caches, batch=max_slots)
            self.plan = plan
            psh = plan.named(self._mesh)
            csh = plan.cache_named(self._mesh)
            rep = NamedSharding(self._mesh, P())
            # commit params/caches to the mesh once; on recovery this is
            # the reshard (old-mesh arrays redistribute onto the survivors)
            self.sparams = jax.device_put(self.sparams, psh)
            self.caches = jax.device_put(self.caches, csh)
            self._empty1 = jax.device_put(
                self._empty1, jax.tree.map(lambda _: rep, self._empty1))
            # explicit in/out shardings on the decode step: params keep the
            # Megatron column/row placement (one all-reduce per block half),
            # caches stay put so donation round-trips without resharding
            step_kw = {"in_shardings": ((psh, csh,
                                         rep if self._paged else None)
                                        + (rep,) * 8),
                       "out_shardings": (rep, csh)}
            cache_kw = {"out_shardings": csh}

        self._prefill = jax.jit(
            lambda sp, x: MD.prefill(sp, cfg, x, rt, max_len=max_len))
        self._step = jax.jit(self._step_fn, donate_argnums=(1,), **step_kw)
        self._insert = jax.jit(self._insert_fn, donate_argnums=(0,),
                               **cache_kw)
        self._insert_paged = jax.jit(self._insert_paged_fn,
                                     donate_argnums=(0,), **cache_kw)
        self._insert_shared = jax.jit(self._insert_shared_fn,
                                      donate_argnums=(0,), **cache_kw)
        self._copy_page = jax.jit(self._copy_page_fn, donate_argnums=(0,),
                                  **cache_kw)
        self._scrub = jax.jit(self._scrub_fn, donate_argnums=(0,), **cache_kw)
        self._scrub_slot = jax.jit(self._scrub_slot_fn, donate_argnums=(0,),
                                   **cache_kw)
        self._sample1 = jax.jit(
            lambda lg, uid, ctr, temp: sample_token(
                lg, self._fold_key(uid, ctr), temp, self._top_k))

    # -- layer-layout structure helpers -----------------------------------

    def _build_layer_specs(self, page_size: int, num_pages: int) -> dict:
        """Resolve every layer's serving CacheSpec (the tagged slot-state
        union: paged / full / ring KV, mamba / rwkv / gla recurrent state).
        Keyed like the cache pytree: one spec per scanned pattern position
        plus one per unrolled tail layer."""
        cfg = self.cfg
        kinds = cfg.layer_kinds()
        plen = len(cfg.layer_pattern)
        n_groups, tail = (divmod(cfg.n_layers, plen) if cfg.scan_layers
                          else (0, cfg.n_layers))

        def spec(kind):
            return layer_cache_spec(cfg, kind, self.max_slots, self.max_len,
                                    self.rt, self._cache_dtype,
                                    page_size=page_size, num_pages=num_pages)

        stacked = (tuple(spec(k) for k in cfg.layer_pattern)
                   if n_groups else None)
        return {"stacked": stacked,
                "tail": tuple(spec(kinds[n_groups * plen + i])
                              for i in range(tail))}

    def layout_summary(self) -> list[dict]:
        """Ordered per-layer {layer, kind, layout} — the engine's resolved
        slot-state union over the whole stack (see README "serving the
        model zoo")."""
        kinds = self.cfg.layer_kinds()
        sp = self._layer_specs
        n_tail = len(sp["tail"])
        n_scanned = self.cfg.n_layers - n_tail
        out = []
        for i in range(self.cfg.n_layers):
            spec = (sp["stacked"][i % len(self.cfg.layer_pattern)]
                    if i < n_scanned else sp["tail"][i - n_scanned])
            out.append({"layer": i, "kind": kinds[i], "layout": spec.layout})
        return out

    def _has_non_paged_rows(self) -> bool:
        """True when any layer keeps per-slot (non-arena) state — ring
        caches or recurrent states that prefix reuse must snapshot."""
        flags = list(self._paged_stacked) + list(self._paged_tail)
        return any(not f for f in flags)

    def _compute_page_bytes(self) -> int:
        """Device bytes per pool page, summed over every paged layer (scan
        groups included: a stacked arena leaf is (G, P, ...))."""
        total = 0
        for flags, layers, ax in ((self._paged_stacked,
                                   self.caches["stacked"] or (), 1),
                                  (self._paged_tail, self.caches["tail"], 0)):
            for paged, layer in zip(flags, layers):
                if paged:
                    total += sum(leaf.nbytes // leaf.shape[ax]
                                 for leaf in layer.values())
        return total

    def _autotune_warmup(self) -> None:
        """Tune every (op, shape) the serving steps will trace, eagerly.

        GEMM shapes: the standard transformer projection pairs at the decode
        row count (``max_slots``) and the streaming-prefill pack length
        (``self._chunk``).  Attention: one entry per layer-kind
        (sink, window) at the decode cache length.  Shapes that miss at
        trace time (exotic archetypes, odd prefill prefixes) fall back to
        the deterministic perfmodel ranking — same impl family, still zero
        timed runs inside the trace.
        """
        from repro.kernels import autotune
        cfg, tc, rt = self.cfg, self.cfg.ternary, self.rt
        cache = autotune.default_cache()
        before = cache.timed_runs
        das = tc.das if (tc.enabled and tc.das is not None) else None
        pairs = {(cfg.d_model, cfg.q_dim), (cfg.d_model, cfg.kv_dim),
                 (cfg.q_dim, cfg.d_model), (cfg.d_model, cfg.d_ff),
                 (cfg.d_ff, cfg.d_model)}
        for m in sorted({self.max_slots, self._chunk}):
            for k, n in sorted(pairs):
                if das is not None:
                    autotune.tune("das_ternary_gemm", cache=cache, m=m, k=k,
                                  n=n, keep=das.keep, block=das.block)
                else:
                    autotune.tune("ternary_gemm", cache=cache, m=m, k=k, n=n,
                                  keep=0, block=0)
        for kind in set(cfg.layer_kinds()) & {"attn", "local"}:
            sink, window = A.kind_sink_window(cfg, kind, rt.serve_sparse)
            lk = (sink + window) if sink < A.FULL_SINK else self.max_len
            autotune.tune("sparse_attn", cache=cache, **autotune.attn_dims(
                hq=cfg.n_heads, hkv=cfg.n_kv_heads, lq=1, lk=lk,
                d=cfg.head_dim_, sink=sink, window=window))
        self.stats.autotune_timed_runs += cache.timed_runs - before

    # -- jitted pieces ----------------------------------------------------

    def _fold_key(self, uid, counter):
        return jax.random.fold_in(jax.random.fold_in(self._base_key, uid),
                                  counter)

    def _step_fn(self, sparams, caches, pt, tok, t, temps, uids, counters,
                 active, forced, forced_x):
        """One batched decode tick: embed -> decode_step -> sample.

        tok (B,) int32 inputs; t (B,) per-sequence positions (paged layout:
        -1 on inactive rows routes their writes to the null page); pt is the
        (B, pages_per_seq) page table (None under per-slot layouts) — passed
        as plain data so host-side page allocation never retraces; forced/
        forced_x override the input with raw prompt embeddings for
        stub-frontend models still absorbing their prompt tail.
        """
        if self._uses_embeds:
            x = jnp.take(sparams["embed"], tok, axis=0).astype(jnp.float32)
            x = jnp.where(forced[:, None], forced_x, x)[:, None, :]
            logits, caches = MD.decode_step(sparams, self.cfg, caches, x, t,
                                            self.rt, pt)
        else:
            logits, caches = MD.decode_step(sparams, self.cfg, caches, tok, t,
                                            self.rt, pt)
        keys = jax.vmap(self._fold_key)(uids, counters)
        next_tok = self._sampler(logits, keys, temps)
        next_tok = jnp.where(active, next_tok, 0)
        return next_tok, caches

    def _insert_fn(self, big, small, slot):
        """Overwrite one slot's rows with a batch-1 cache pytree."""
        stacked = None
        if big["stacked"] is not None:
            stacked = jax.tree.map(lambda bg, sm: bg.at[:, slot].set(
                sm[:, 0].astype(bg.dtype)), big["stacked"], small["stacked"])
        tail = jax.tree.map(lambda bg, sm: bg.at[slot].set(
            sm[0].astype(bg.dtype)), big["tail"], small["tail"])
        return {"stacked": stacked, "tail": tail}

    # -- paged-layout jitted pieces ---------------------------------------
    # All trace once per engine: layer structure (which layers are arenas)
    # is static, page ids / slot index are data.

    def _insert_paged_fn(self, big, small, slot, page_vec):
        """Insert a fresh batch-1 prefill under the paged layout: dense full
        caches scatter page-by-page into the arenas at ``page_vec`` (0 =
        unmapped -> lands in the null page, whose positions stay -1 since
        unprefilled dense rows carry pos -1); per-slot layers row-copy."""
        ps, n = self._page_size, self._pages_per_seq

        def paged(bg, sm, stacked):
            def put(pages, dense):
                if stacked:    # (G, P, ps, ...) <- (G, 1, n*ps, ...)
                    rows = dense[:, 0].reshape(
                        (dense.shape[0], n, ps) + dense.shape[3:])
                    return pages.at[:, page_vec].set(rows.astype(pages.dtype))
                rows = dense[0].reshape((n, ps) + dense.shape[2:])
                return pages.at[page_vec].set(rows.astype(pages.dtype))
            return {"k_pages": put(bg["k_pages"], sm["k"]),
                    "v_pages": put(bg["v_pages"], sm["v"]),
                    "pos_pages": put(bg["pos_pages"], sm["pos"])}

        def rows(bg, sm, stacked):
            if stacked:
                return jax.tree.map(lambda b_, s_: b_.at[:, slot].set(
                    s_[:, 0].astype(b_.dtype)), bg, sm)
            return jax.tree.map(lambda b_, s_: b_.at[slot].set(
                s_[0].astype(b_.dtype)), bg, sm)

        stacked = None
        if big["stacked"] is not None:
            stacked = tuple(
                paged(bg, sm, True) if is_p else rows(bg, sm, True)
                for is_p, bg, sm in zip(self._paged_stacked, big["stacked"],
                                        small["stacked"]))
        tail = tuple(
            paged(bg, sm, False) if is_p else rows(bg, sm, False)
            for is_p, bg, sm in zip(self._paged_tail, big["tail"],
                                    small["tail"]))
        return {"stacked": stacked, "tail": tail}

    def _insert_shared_fn(self, big, rest, slot):
        """Restore a prefix entry's snapshot of the NON-paged layers into
        one slot's rows (arenas untouched: shared pages arrive via the page
        table).  ``rest`` mirrors the cache structure with paged layers
        replaced by empty tuples (_snapshot_rest)."""
        def one(bg, sm, stacked):
            if KV.is_paged(bg):
                return bg
            if stacked:
                return jax.tree.map(lambda b_, s_: b_.at[:, slot].set(
                    s_[:, 0].astype(b_.dtype)), bg, sm)
            return jax.tree.map(lambda b_, s_: b_.at[slot].set(
                s_[0].astype(b_.dtype)), bg, sm)

        stacked = None
        if big["stacked"] is not None:
            stacked = tuple(one(bg, sm, True) for bg, sm in
                            zip(big["stacked"], rest["stacked"]))
        tail = tuple(one(bg, sm, False) for bg, sm in
                     zip(big["tail"], rest["tail"]))
        return {"stacked": stacked, "tail": tail}

    def _snapshot_rest(self, small):
        """Host (numpy) snapshot of the non-paged layers of a batch-1 cache
        pytree, with paged layers as empty tuples; None when every layer is
        paged (nothing beyond pages to restore)."""
        if self._rest_is_empty:
            return None

        def one(is_p, sm):
            return () if is_p else jax.tree.map(np.asarray,
                                                jax.device_get(sm))
        stacked = None
        if small["stacked"] is not None:
            stacked = tuple(one(is_p, sm) for is_p, sm in
                            zip(self._paged_stacked, small["stacked"]))
        tail = tuple(one(is_p, sm) for is_p, sm in
                     zip(self._paged_tail, small["tail"]))
        return {"stacked": stacked, "tail": tail}

    def _copy_page_fn(self, caches, src, dst):
        """Copy-on-write: duplicate arena page ``src`` into ``dst`` in every
        paged layer."""
        def one(is_p, layer, stacked):
            if not is_p:
                return layer
            if stacked:
                return {k: v.at[:, dst].set(v[:, src])
                        for k, v in layer.items()}
            return {k: v.at[dst].set(v[src]) for k, v in layer.items()}

        stacked = None
        if caches["stacked"] is not None:
            stacked = tuple(one(is_p, c, True) for is_p, c in
                            zip(self._paged_stacked, caches["stacked"]))
        tail = tuple(one(is_p, c, False) for is_p, c in
                     zip(self._paged_tail, caches["tail"]))
        return {"stacked": stacked, "tail": tail}

    def _scrub_fn(self, caches, ids):
        """Reset pos_pages to -1 for the (fixed-length, 0-padded) page-id
        vector ``ids`` — freed pages must be masked before reuse (the null
        page 0 is always -1, so padding is harmless)."""
        def one(is_p, layer, stacked):
            if not is_p:
                return layer
            pp = layer["pos_pages"]
            pp = pp.at[:, ids].set(-1) if stacked else pp.at[ids].set(-1)
            return {**layer, "pos_pages": pp}

        stacked = None
        if caches["stacked"] is not None:
            stacked = tuple(one(is_p, c, True) for is_p, c in
                            zip(self._paged_stacked, caches["stacked"]))
        tail = tuple(one(is_p, c, False) for is_p, c in
                     zip(self._paged_tail, caches["tail"]))
        return {"stacked": stacked, "tail": tail}

    def _scrub_slot_fn(self, big, empty, slot):
        """Retirement hygiene: reset one slot's rows in every NON-paged
        layer back to the empty cache (full/ring rows to pos -1, recurrent
        states and ssd replay buffers to zeros).  Admission always
        overwrites these rows anyway, but scrubbing at retirement keeps a
        finished request's KV and state from outliving it — no layout of
        the union is exempt (paged arenas are scrubbed page-wise by
        `_scrub_pages` instead)."""
        def one(is_p, bg, sm, stacked):
            if is_p:
                return bg
            if stacked:
                return jax.tree.map(lambda b_, s_: b_.at[:, slot].set(
                    s_[:, 0].astype(b_.dtype)), bg, sm)
            return jax.tree.map(lambda b_, s_: b_.at[slot].set(
                s_[0].astype(b_.dtype)), bg, sm)

        stacked = None
        if big["stacked"] is not None:
            stacked = tuple(one(is_p, bg, sm, True) for is_p, bg, sm in
                            zip(self._paged_stacked, big["stacked"],
                                empty["stacked"]))
        tail = tuple(one(is_p, bg, sm, False) for is_p, bg, sm in
                     zip(self._paged_tail, big["tail"], empty["tail"]))
        return {"stacked": stacked, "tail": tail}

    def _scrub_pages(self, freed: list) -> None:
        """Host wrapper: scrub freed pages in fixed-size batches so the
        jitted scrub never retraces."""
        if not freed or not self._pages_per_seq:
            return
        w = self._pages_per_seq
        for i in range(0, len(freed), w):
            ids = np.zeros(w, np.int32)
            chunk = freed[i:i + w]
            ids[:len(chunk)] = chunk
            self.caches = self._scrub(self.caches, jnp.asarray(ids))

    # -- public API -------------------------------------------------------

    def validate(self, req: Request) -> None:
        """Shape/capacity checks for a prospective request (raises
        ValueError).  Pure read — safe to call from any thread before
        handing the request to `submit` (the HTTP front door validates in
        its event loop so a bad request 400s without touching the engine
        thread)."""
        if req.prompt_len < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.uid}: max_new_tokens must be >= 1")
        if self._has_full and req.prompt_len + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt {req.prompt_len} + gen "
                f"{req.max_new_tokens} exceeds max_len {self.max_len} "
                f"(a full-cache layer is active)")
        if self._pages_per_seq:
            worst = -(-(req.prompt_len + req.max_new_tokens)
                      // self._page_size)
            usable = self._pool.num_pages - 1
            if worst > usable:
                raise ValueError(
                    f"request {req.uid}: needs up to {worst} KV pages but "
                    f"the pool holds {usable} (raise num_pages or page_size)")

    def submit(self, req: Request) -> None:
        self.validate(req)
        with self._lock:
            # duplicate uids among in-flight work would collide in the
            # results dict AND share a sampling-key stream (correlated
            # draws); a finished-but-unclaimed result would be clobbered —
            # pop_result/drain_results release the uid for reuse
            in_flight = {s.req.uid for s in self._slots if s.req is not None}
            in_flight |= {snap["req"].uid for snap in self._replays}
            if req.uid in in_flight or req.uid in self._pending_uids:
                raise ValueError(f"request uid {req.uid} already in flight")
            if req.uid in self._results:
                raise ValueError(
                    f"request uid {req.uid} has an unclaimed result; "
                    f"pop_result/drain_results it before resubmitting")
            self._pending_uids.add(req.uid)
            self.scheduler.add(req)

    def pop_result(self, uid: int) -> RequestResult | None:
        """Claim (and remove) one finished result, releasing its uid for
        reuse; None when the uid has no finished result yet.  The
        long-running counterpart of `run()`'s bulk drain — an always-on
        server pops each result as it streams out so `_results` stays
        bounded and uids can cycle."""
        with self._lock:
            return self._results.pop(uid, None)

    def drain_results(self) -> dict[int, RequestResult]:
        """Claim every finished result (uid -> RequestResult), releasing
        all their uids for reuse."""
        with self._lock:
            out, self._results = self._results, {}
            return out

    def kernel_fallback_deltas(self) -> dict:
        """THIS engine's silent jnp-reference fallbacks: the process-wide
        kernels/ops counters minus the baseline snapshotted at
        construction / reset_clock, so co-resident engines (two engines in
        one benchmark process) never attribute each other's fallbacks."""
        out = {}
        for (op, key), cnt in ops.fallback_counts().items():
            delta = cnt - self._fallback_base.get((op, key), 0)
            if delta > 0:
                out[f"{op}{key}"] = delta
        return out

    @property
    def num_active(self) -> int:
        return sum(s.state != FREE for s in self._slots)

    def reset_clock(self) -> None:
        """Zero the virtual clock and stats between traces (caches and jit
        compilation caches survive — use to warm up before a timed replay).
        Only valid when the engine is drained."""
        if self.num_active or self.scheduler or self._replays:
            raise RuntimeError("reset_clock on a non-drained engine")
        self.vtime = 0
        self.stats = EngineStats(
            max_slots=self.max_slots,
            autotune_timed_runs=self.stats.autotune_timed_runs)
        self._fallback_base = dict(ops.fallback_counts())

    def timed_replay(self, trace) -> dict[int, RequestResult]:
        """Replay `trace` twice — once to pay the XLA compiles, then timed
        with warm caches — and return the timed run's results (wall-clock
        stats reflect only the second replay)."""
        for r in trace:
            self.submit(r)
        self.run()
        self.reset_clock()
        for r in trace:
            self.submit(r)
        return self.run()

    def run(self) -> dict[int, RequestResult]:
        """Drain the queue; returns uid -> RequestResult."""
        t0 = time.perf_counter()
        while self.scheduler or self.num_active or self._replays:
            self._admit_ready()
            if not self.num_active:
                if self._replays:
                    continue      # deferred replay admission: retry
                nxt = self.scheduler.next_arrival()
                if nxt is None:   # nothing queued, nothing active
                    break
                self.vtime = max(self.vtime, nxt)   # idle fast-forward
                continue
            try:
                self.step_decode()
            except fault.WorkerFailure:
                self.recover()
        self.stats.wall_seconds += time.perf_counter() - t0
        # surface THIS engine's silent jnp-reference fallbacks (deltas vs
        # the per-engine baseline; a populated dict under a kernel mode
        # means some layer shapes are not slab-aligned and are quietly
        # running the slow reference path)
        self.stats.kernel_fallbacks = self.kernel_fallback_deltas()
        return self.drain_results()

    def run_forever(self, *, should_stop=None, poll=None,
                    idle_wait=None) -> None:
        """Always-on step-driver: the sibling of `run()` the HTTP front
        door owns.  Never drains `_results` — callers consume results
        incrementally via `on_finish` / `pop_result` (which is what keeps
        memory and the uid space bounded over an unbounded request
        stream).

        should_stop: checked once per iteration; True exits the loop.
        poll: called once per iteration before admission — the server
            drains its thread-safe submission inbox here so `submit` runs
            on the engine thread (the event loop never blocks on a jitted
            prefill).
        idle_wait: called when there is nothing active, nothing admissible
            and nothing future-dated — should block briefly for new work
            (e.g. wait on an event) and return False to exit.  When None,
            an idle engine exits (drain-and-return semantics, like run()).

        Future-dated arrivals still fast-forward the virtual clock, so a
        replayed trace behaves exactly as under `run()`.
        """
        t0 = time.perf_counter()
        try:
            while True:
                if should_stop is not None and should_stop():
                    break
                if poll is not None:
                    poll()
                self._admit_ready()
                if self.num_active:
                    try:
                        self.step_decode()
                    except fault.WorkerFailure:
                        self.recover()
                    continue
                if self._replays:
                    continue      # deferred replay admission: retry
                nxt = self.scheduler.next_arrival()
                if nxt is not None:
                    if nxt > self.vtime:
                        self.vtime = nxt   # idle fast-forward
                    # else: a deferred (paged-pool) admission retries next
                    # iteration at the same vtime
                    continue
                if idle_wait is None or idle_wait() is False:
                    break
        finally:
            self.stats.wall_seconds += time.perf_counter() - t0
            self.stats.kernel_fallbacks = self.kernel_fallback_deltas()

    # -- elastic recovery --------------------------------------------------

    @property
    def topology(self):
        """The live Topology (None single-device); shrinks on recovery."""
        return self._topology

    def recover(self, lost_devices: int | None = None) -> None:
        """Survive a device/host loss mid-serving: snapshot every active
        slot (request + tokens generated so far), shrink the topology by
        ``lost_devices`` (default ``fault_lost_devices``; tp is preserved
        while it divides the survivor count, per elastic.plan_remesh),
        rebuild mesh/plan/caches/jits, and queue the snapshots for replay
        admission — in-flight requests resume from their last token, never
        dropped.  Single-device engines rebuild in place (lost capacity 0).
        """
        t0 = time.perf_counter()
        with self._lock:
            snaps = []
            for s in self._slots:
                if s.state == FREE:
                    continue
                snaps.append({
                    "req": s.req, "out": list(s.out),
                    "admit_vtime": s.admit_vtime,
                    # no first token yet -> let replay stamp it on emission
                    "first_tok_vtime": s.first_tok_vtime if s.out else None,
                    "admitted_with_active": s.admitted_with_active})
                s.state = FREE
                s.req = None
                s.input_x = None
                s.tail = None
                s.pages = None
                s.page_budget = 0
            lost = (self.fault_lost_devices if lost_devices is None
                    else lost_devices)
            if self._topology is not None and lost > 0:
                self._topology = self._topology.shrink(
                    self._topology.n_devices - lost)
            self._build_device_state()
            self._replays.extend(snaps)
            self.stats.reshards += 1
            dt = time.perf_counter() - t0
            self.stats.recovery_seconds += dt
        if self.telemetry is not None:
            self.telemetry.on_reshard(self, lost=lost, seconds=dt,
                                      in_flight=len(snaps))

    # -- admission --------------------------------------------------------

    def _admit_ready(self) -> None:
        with self._lock:
            self._admit_ready_locked()

    def _maybe_preempt(self) -> None:
        """Deadline rescue: when every slot is busy and the queue head
        would miss its SLO even if admitted right now, truncate-and-retire
        the YOUNGEST active slot whose own deadline has already passed
        (its result is delivered as-is with ``preempted=True``).  Work
        that can still meet its SLO is never preempted, and requests
        without an SLO have no budget to be over — they are left alone."""
        if self.num_active < self.max_slots:
            return
        head = self.scheduler.peek_ready(self.vtime)
        if head is None or head.slo_steps is None:
            return
        slack = head.arrival + head.slo_steps - self.vtime
        # steps to finish once admitted: the unabsorbed prompt tail feeds
        # one token per tick, then one tick per generated token
        prefix = (head.prompt_len // self._chunk) * self._chunk
        needed = (head.prompt_len - prefix) + head.max_new_tokens
        if slack > needed:
            return   # still meetable without making room
        victim = None
        for i, s in enumerate(self._slots):
            if s.state != DECODE or s.req is None or s.req.slo_steps is None:
                continue
            if self.vtime <= s.req.arrival + s.req.slo_steps:
                continue   # within budget: not preemptible
            if victim is None or s.admit_vtime > self._slots[victim].admit_vtime:
                victim = i
        if victim is not None:
            self.stats.preemptions += 1
            self._retire(victim, preempted=True)

    def _admit_ready_locked(self) -> None:
        # recovery replays outrank fresh admissions: these requests were
        # already mid-stream when the failure hit and must never be dropped
        while self._replays:
            idx = next((i for i, s in enumerate(self._slots)
                        if s.state == FREE), None)
            if idx is None:
                break
            if not self._replay_admit(idx, self._replays[0]):
                break   # pool too tight right now: retry next tick
            self._replays.pop(0)
        if self.policy == "wave" and self.num_active:
            return
        if self._preempt:
            self._maybe_preempt()
        for i, slot in enumerate(self._slots):
            if slot.state != FREE:
                continue
            if self._moe_slot_cap and self.num_active >= self._moe_slot_cap:
                # expert-capacity accounting: each active slot contributes at
                # most one token per decode tick, and one expert can receive
                # at most one routed copy of each token — so active slots ==
                # the worst-case per-expert load.  Hold admissions until a
                # retirement frees capacity (never drop tokens mid-decode).
                nxt = self.scheduler.next_arrival()
                if nxt is not None and nxt <= self.vtime:
                    self.stats.moe_capacity_deferrals += 1
                return
            req = self.scheduler.pop_ready(self.vtime)
            if req is None:
                return
            if not self._admit(i, req):
                # pool too tight right now: requeue and retry next tick
                # (active slots retiring / evictions will free pages; with
                # zero active slots every non-slot page is evictable, so
                # the submit-time capacity check guarantees progress)
                self._pending_uids.add(req.uid)
                self.scheduler.add(req)
                return

    def _admit(self, idx: int, req: Request) -> bool:
        """Claim slot ``idx`` for ``req``; False defers admission (paged
        layout only: the pool cannot cover the request's worst case yet)."""
        slot = self._slots[idx]
        p = req.prompt_len
        prefix = (p // self._chunk) * self._chunk
        self._pending_uids.discard(req.uid)
        # mid-decode admission metric: slots already mid-stream (admitted in
        # an EARLIER tick) — same-tick co-admissions don't count
        slot.admitted_with_active = sum(
            1 for s2 in self._slots
            if s2.state != FREE and s2.admit_vtime < self.vtime)
        slot.req = req
        slot.admit_vtime = self.vtime
        slot.out = []
        slot.input_x = None
        slot.first_tok_vtime = None
        if self._paged:
            ok = self._admit_paged(idx, slot, req, prefix)
            if not ok:
                slot.req = None     # back off: slot stays FREE
            return ok
        self._admit_dense(idx, slot, req, prefix)
        return True

    def _replay_admit(self, idx: int, snap: dict) -> bool:
        """Re-admit a snapshot taken by `recover()`: prefill the original
        prompt prefix, then force-feed prompt tail + already-generated
        tokens, so the slot's caches and sampling counters land exactly
        where they were — in-flight requests resume, never restart."""
        slot = self._slots[idx]
        req = snap["req"]
        prefix = (req.prompt_len // self._chunk) * self._chunk
        slot.admitted_with_active = snap["admitted_with_active"]
        slot.req = req
        slot.admit_vtime = snap["admit_vtime"]
        slot.out = list(snap["out"])
        slot.input_x = None
        slot.first_tok_vtime = None
        replay = tuple(snap["out"])
        if self._paged:
            if not self._admit_paged(idx, slot, req, prefix,
                                     replay=replay, notify=False):
                slot.req = None     # back off: slot stays FREE
                return False
        else:
            self._admit_dense(idx, slot, req, prefix,
                              replay=replay, notify=False)
        if snap["first_tok_vtime"] is not None:
            slot.first_tok_vtime = snap["first_tok_vtime"]
        return True

    def _admit_dense(self, idx: int, slot: _Slot, req: Request,
                     prefix: int, replay: tuple = (),
                     notify: bool = True) -> None:
        p = req.prompt_len
        if prefix > 0:
            logits, small = self._prefill(self.sparams,
                                          jnp.asarray(req.prompt)[None, :prefix])
            self.stats.prefill_tokens += prefix
        else:
            logits, small = None, self._empty1
        self.caches = self._insert(self.caches, small, jnp.int32(idx))
        self._start_slot(idx, slot, req, prefix,
                         logits[0] if logits is not None else None,
                         replay=replay, notify=notify)

    def _feed(self, slot: _Slot, nxt) -> None:
        """Route one tail element into the decode step's input: raw
        embedding rows through forced_x, token ids through input_tok (a
        replayed tail mixes both for stub-frontend models — prompt rows are
        vectors, previously generated tokens are ids)."""
        if self._uses_embeds and np.ndim(nxt) > 0:
            slot.input_tok = 0
            slot.input_x = np.asarray(nxt, np.float32)
        else:
            slot.input_tok = int(nxt)
            slot.input_x = None

    def _start_slot(self, idx: int, slot: _Slot, req: Request,
                    absorbed: int, logits, replay: tuple = (),
                    notify: bool = True) -> None:
        """Common tail of admission: first token from prefill/stored logits
        when the whole prompt is absorbed, else token-by-token tail feed
        from position ``absorbed``.  ``replay`` (elastic recovery) appends
        already-generated tokens to the tail so the slot re-derives its
        exact pre-failure state through the same forced-feed machinery —
        sampling resumes at counter len(out), bitwise-continuing the
        original stream."""
        p = req.prompt_len
        if notify and self.telemetry is not None:
            self.telemetry.on_admit(req, self.vtime)
        if absorbed == p and not replay:
            tok = int(self._sample1(jnp.asarray(logits), jnp.int32(req.uid),
                                    jnp.int32(len(slot.out)),
                                    jnp.float32(req.temperature)))
            slot.state = DECODE
            if slot.first_tok_vtime is None:
                slot.first_tok_vtime = self.vtime
            slot.out.append(tok)
            slot.input_tok = tok
            slot.input_pos = p
            self.stats.generated_tokens += 1
            if self.on_token is not None:
                self.on_token(req.uid, tok)
            if self._finished(slot, tok):
                self._retire(idx)
        else:
            slot.state = PREFILL
            slot.tail = list(req.prompt[absorbed:]) + list(replay)
            slot.tail_idx = 1
            slot.input_pos = absorbed
            self._feed(slot, slot.tail[0])

    # -- paged admission ---------------------------------------------------

    def _admit_paged(self, idx: int, slot: _Slot, req: Request,
                     prefix: int, replay: tuple = (),
                     notify: bool = True) -> bool:
        p, g, ps = req.prompt_len, req.max_new_tokens, self._page_size
        n_seq = self._pages_per_seq
        tokens = None
        if self._share and not self._uses_embeds:
            tokens = tuple(int(t) for t in np.asarray(req.prompt))

        # -- choose the best cached prefix --------------------------------
        # exact entry: pages + per-slot states + logits, bitwise-identical
        # to a fresh prefill of that prefix.  page-donor: whole pages inside
        # the longest common prefix with any stored prompt — reusable alone
        # only when every layer is paged (no ring/recurrent state to miss).
        shared_len, kind, entry = 0, None, None
        if tokens is not None:
            best, donor, common = self._radix.lookup(tokens)
            if best is not None and best.length >= 1:
                shared_len, kind, entry = best.length, "exact", best
            if self._rest_is_empty and donor is not None and n_seq:
                l_pages = (min(common, p - 1) // ps) * ps  # keep >=1 to feed
                if l_pages > shared_len:
                    shared_len, kind, entry = l_pages, "pages", donor

        total = -(-(p + g) // ps) if n_seq else 0
        register = self._share and tokens is not None and prefix > 0
        while True:
            if kind == "exact":
                n_cov = -(-shared_len // ps) if n_seq else 0
                shared_pages = tuple(entry.pages[:n_cov])
                # +1: a partial boundary page pinned by the trie gets CoW'd
                # on this slot's first write into it
                budget = (total - n_cov + (1 if shared_len % ps else 0)) \
                    if n_seq else 0
                immediate = 0
            elif kind == "pages":
                n_cov = shared_len // ps
                shared_pages = tuple(entry.pages[:n_cov])
                budget = total - n_cov
                immediate = 0
            else:
                n_cov, shared_pages = 0, ()
                immediate = -(-prefix // ps) if n_seq else 0
                budget = total + (1 if n_seq and register and prefix % ps
                                  else 0)
            if not n_seq or self._paged_room(budget, shared_pages):
                break
            # headroom short for this plan: degrade before deferring --
            # shared reuse -> fresh w/ registration -> fresh w/o -> defer.
            # The bare fresh plan needs exactly ``total`` pages, which the
            # submit-time capacity check bounds, so with zero active slots
            # (everything evictable) admission always eventually succeeds.
            if kind is not None:
                kind, entry, shared_len = None, None, 0
            elif register and prefix % ps:
                register = False
            else:
                return False

        # -- populate the slot's page table -------------------------------
        pages = [0] * max(n_seq, 1)
        if kind is not None:
            if shared_pages:
                self._pool.retain(shared_pages)
            pages[:len(shared_pages)] = [int(x) for x in shared_pages]
            entry.last_used = self.vtime
            entry.hits += 1
            self.stats.prefix_hits += 1
            self.stats.prompt_tokens_reused += shared_len
            if kind == "exact" and entry.state is not None:
                self.caches = self._insert_shared(self.caches, entry.state,
                                                  jnp.int32(idx))
            logits = entry.logits if (kind == "exact" and shared_len == p) \
                else None
            absorbed = shared_len
        else:
            if prefix > 0:
                lg, small = self._prefill(
                    self.sparams, jnp.asarray(req.prompt)[None, :prefix])
                self.stats.prefill_tokens += prefix
            else:
                lg, small = None, self._empty1
            fresh = [self._alloc_page() for _ in range(immediate)]
            pages[:len(fresh)] = fresh
            page_vec = np.zeros(max(n_seq, 1), np.int32)
            page_vec[:len(fresh)] = fresh
            self.caches = self._insert_paged(self.caches, small,
                                            jnp.int32(idx),
                                            jnp.asarray(page_vec))
            if register:
                ent = PrefixEntry(length=prefix, pages=tuple(fresh),
                                  state=self._snapshot_rest(small),
                                  logits=np.asarray(lg[0]),
                                  last_used=self.vtime)
                if self._radix.insert(tokens[:prefix], ent) and fresh:
                    self._pool.retain(fresh)
            logits = lg[0] if (lg is not None and prefix == p) else None
            absorbed = prefix
            budget -= immediate

        slot.pages = pages
        slot.page_budget = budget
        self._pt[idx, :] = pages
        if self._pool is not None:
            self.stats.pool_peak_pages = max(self.stats.pool_peak_pages,
                                             self._pool.pages_in_use)
        self._start_slot(idx, slot, req, absorbed, logits,
                         replay=replay, notify=notify)
        return True

    def _paged_room(self, need_new: int, reserve_exclude=()) -> bool:
        """Best-effort admission control: can the pool cover ``need_new``
        future allocations on top of every active slot's outstanding budget?
        Free pages plus trie-only (evictable) pages count; pages the request
        is about to retain are excluded.  Conservative against generation
        worst cases but not a hard guarantee — an exhausted pool raises at
        allocation time."""
        free = self._pool.free_count
        hold: dict[int, int] = {}
        for _, e in self._radix.items() if self._radix is not None else ():
            for pg in e.pages:
                hold[pg] = hold.get(pg, 0) + 1
        excl = {int(x) for x in reserve_exclude}
        evictable = sum(1 for pg, c in hold.items()
                        if pg not in excl and self._pool.refs[pg] == c)
        outstanding = sum(s.page_budget for s in self._slots
                          if s.state != FREE)
        return need_new + outstanding <= free + evictable

    def _alloc_page(self) -> int:
        pg = self._pool.alloc()
        while pg is None:
            if not self._evict_one():
                raise RuntimeError(
                    "kv page pool exhausted: every page is pinned by an "
                    "active slot (raise num_pages)")
            pg = self._pool.alloc()
        return pg

    def _evict_one(self) -> bool:
        """Drop the least-recently-used prefix entry, freeing its pages
        (those not also held by active slots)."""
        if self._radix is None or not len(self._radix):
            return False
        lru_toks, lru_used = None, None
        for toks, e in self._radix.items():
            if lru_used is None or e.last_used < lru_used:
                lru_toks, lru_used = toks, e.last_used
        entry = self._radix.remove(lru_toks)
        self._scrub_pages(self._pool.release(entry.pages))
        self.stats.prefix_evictions += 1
        return True

    def _ensure_writable_pages(self) -> None:
        """Pre-tick page-fault pass: every active slot's write position this
        tick must map a page this slot owns exclusively.  Null mapping ->
        lazy alloc; shared mapping (refcount > 1) -> copy-on-write."""
        ps = self._page_size
        for i, s in enumerate(self._slots):
            if s.state == FREE:
                continue
            pi = s.input_pos // ps
            phys = s.pages[pi]
            if phys == 0:
                new = self._alloc_page()
                s.pages[pi] = new
                self._pt[i, pi] = new
                s.page_budget = max(s.page_budget - 1, 0)
            elif self._pool.refs[phys] > 1:
                new = self._alloc_page()
                self.caches = self._copy_page(self.caches, jnp.int32(phys),
                                              jnp.int32(new))
                self._pool.release([phys])   # others still hold it: no free
                s.pages[pi] = new
                self._pt[i, pi] = new
                s.page_budget = max(s.page_budget - 1, 0)
                self.stats.cow_copies += 1
        self.stats.pool_peak_pages = max(self.stats.pool_peak_pages,
                                         self._pool.pages_in_use)

    # -- the decode tick --------------------------------------------------

    def step_decode(self) -> None:
        tick_t0 = time.perf_counter()
        if self.fault_injector is not None:
            # simulated device/host loss lands here, mid-serving; the run
            # loops catch WorkerFailure and call recover()
            self.fault_injector.maybe_fail(self.stats.decode_steps)
        b = self.max_slots
        tok = np.zeros((b,), np.int32)
        # paged: inactive rows carry t = -1 so their writes land on the null
        # page with pos -1 (keeping it permanently masked); dense layouts
        # keep the historical t = 0 don't-care
        t = np.full((b,), -1 if self._paged else 0, np.int32)
        temps = np.zeros((b,), np.float32)
        uids = np.zeros((b,), np.int32)
        counters = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        forced = np.zeros((b,), bool)
        d_model = self.cfg.d_model
        forced_x = np.zeros((b, d_model), np.float32)
        for i, s in enumerate(self._slots):
            if s.state == FREE:
                continue
            active[i] = True
            tok[i] = s.input_tok
            t[i] = s.input_pos
            temps[i] = s.req.temperature
            uids[i] = s.req.uid
            counters[i] = len(s.out)
            if s.input_x is not None:
                forced[i] = True
                forced_x[i] = s.input_x

        if self._paged and self._pages_per_seq:
            self._ensure_writable_pages()

        pt = jnp.asarray(self._pt) if self._paged else None
        next_tok, self.caches = self._step(
            self.sparams, self.caches, pt, jnp.asarray(tok), jnp.asarray(t),
            jnp.asarray(temps), jnp.asarray(uids), jnp.asarray(counters),
            jnp.asarray(active), jnp.asarray(forced), jnp.asarray(forced_x))
        next_tok = np.asarray(next_tok)

        self.stats.decode_steps += 1
        self.stats.active_slot_steps += int(active.sum())
        self.vtime += 1

        for i, s in enumerate(self._slots):
            if s.state == PREFILL:
                if s.tail_idx < len(s.tail):
                    s.input_pos += 1
                    self._feed(s, s.tail[s.tail_idx])
                    s.tail_idx += 1
                else:
                    # last prompt token went in this tick -> first sample
                    # (a replayed slot keeps its original first-token time)
                    s.state = DECODE
                    s.input_x = None
                    if s.first_tok_vtime is None:
                        s.first_tok_vtime = self.vtime
                    self._deliver(i, int(next_tok[i]))
            elif s.state == DECODE:
                self._deliver(i, int(next_tok[i]))

        if self.telemetry is not None:
            self.telemetry.on_tick(self, int(active.sum()),
                                   time.perf_counter() - tick_t0)

    def _deliver(self, idx: int, tok: int) -> None:
        s = self._slots[idx]
        s.out.append(tok)
        s.input_tok = tok
        s.input_pos = s.req.prompt_len + len(s.out) - 1
        self.stats.generated_tokens += 1
        if self.on_token is not None:
            self.on_token(s.req.uid, tok)
        if self._finished(s, tok):
            self._retire(idx)

    def _finished(self, s: _Slot, tok: int) -> bool:
        return (len(s.out) >= s.req.max_new_tokens
                or (s.req.eos_id is not None and tok == s.req.eos_id))

    def _retire(self, idx: int, preempted: bool = False) -> None:
        s = self._slots[idx]
        r = s.req
        result = RequestResult(
            uid=r.uid, tokens=np.asarray(s.out, np.int32),
            prompt_len=r.prompt_len, arrival=r.arrival,
            admit_vtime=s.admit_vtime, first_token_vtime=s.first_tok_vtime,
            finish_vtime=self.vtime,
            admitted_with_active=s.admitted_with_active,
            slo_steps=r.slo_steps, preempted=preempted)
        self._results[r.uid] = result
        if self._paged and s.pages is not None:
            held = [pg for pg in s.pages if pg]
            if held:
                self._scrub_pages(self._pool.release(held))
            self._pt[idx, :] = 0
            s.pages = None
            s.page_budget = 0
        self.caches = self._scrub_slot(self.caches, self._empty1,
                                       jnp.int32(idx))
        s.state = FREE
        s.req = None
        s.input_x = None
        s.tail = None
        if self.telemetry is not None:
            self.telemetry.on_finish(result, self)
        if self.on_finish is not None:
            self.on_finish(result)

    # -- pool introspection ------------------------------------------------

    def pool_stats(self) -> dict:
        """Paged-pool occupancy snapshot (zeros for dense layouts).

        ``page_bytes`` is the per-page footprint summed across every paged
        layer arena; ``dense_equiv_bytes`` is what the same layers would pin
        under the per-slot full layout (max_slots x max_len rows)."""
        if not self._paged or self._pool is None:
            return {"layout": "dense", "page_size": 0, "num_pages": 0,
                    "pages_in_use": 0, "pages_peak": 0, "page_bytes": 0,
                    "bytes_in_use": 0, "bytes_peak": 0,
                    "dense_equiv_bytes": 0, "prefix_entries": 0}
        peak = max(self.stats.pool_peak_pages, self._pool.pages_in_use)
        return {
            "layout": "paged",
            "page_size": self._page_size,
            "num_pages": self._pool.num_pages,
            "pages_in_use": self._pool.pages_in_use,
            "pages_peak": peak,
            "page_bytes": self._page_bytes,
            "bytes_in_use": self._pool.pages_in_use * self._page_bytes,
            "bytes_peak": peak * self._page_bytes,
            "dense_equiv_bytes": (self.max_slots * self._pages_per_seq
                                  * self._page_bytes),
            "prefix_entries": len(self._radix) if self._radix else 0,
        }
