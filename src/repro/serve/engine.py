"""ServeEngine: slot-based continuous batching over per-sequence KV caches.

The engine owns one decode-cache pytree sized for ``max_slots`` sequences
and runs ONE jitted decode step for the whole batch every tick — the
decode step's shapes are static, so it never recompiles as requests come
and go (admission prefill compiles once per pack-aligned prefix length,
a set bounded by max_len / chunk; `reset_clock` lets benchmarks warm
those caches before a timed replay).  Per-slot lifecycle:

  FREE ──admit──> PREFILL ──tail consumed──> DECODE ──eos/max──> FREE

Admission prefills the longest pack-aligned prompt *prefix* through the
LPSA streaming dataflow (batch=1) and writes the resulting layer caches
into the slot's rows; the remaining prompt tail is fed token-by-token
through the shared batched decode step while the other slots keep
generating (token-level admission, Orca-style).  Because every cache row
carries its own position cursor (models/kvcache.attn_write with t: (B,)),
a slot at prompt position 7 coexists with a slot at decode position 900.

Time is virtual: 1 unit == one batched decode step.  Requests carry
arrival times in the same units so traces replay deterministically, and a
request's tokens are bitwise independent of its batch-mates (per-row
attention masks + per-(uid, token) sampling keys) — see
tests/test_serve_engine.py for the batch-invariance check.

``policy="wave"`` degrades the same machinery to lock-step gang
scheduling (admit only when ALL slots are free, barrier until all
finish): the baseline the benchmarks compare against.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import attention as A
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.serve.sampler import make_sampler, sample_token
from repro.serve.scheduler import FifoScheduler, Request

__all__ = ["ServeEngine", "EngineStats", "RequestResult"]

FREE, PREFILL, DECODE = 0, 1, 2


@dataclass
class RequestResult:
    uid: int
    tokens: np.ndarray            # generated ids (eos included when hit)
    prompt_len: int
    arrival: int                  # vtime units (1 = one batched decode step)
    admit_vtime: int
    first_token_vtime: int
    finish_vtime: int
    admitted_with_active: int = 0  # slots already mid-stream at admission
                                   # (admitted in an earlier tick)

    @property
    def latency_steps(self) -> int:
        return self.finish_vtime - self.arrival

    @property
    def ttft_steps(self) -> int:
        return self.first_token_vtime - self.arrival


@dataclass
class EngineStats:
    max_slots: int = 0
    decode_steps: int = 0         # batched step invocations
    active_slot_steps: int = 0    # sum over steps of |active slots|
    generated_tokens: int = 0     # sampled tokens delivered to requests
    prefill_tokens: int = 0       # prompt tokens absorbed via batch-1 prefill
    wall_seconds: float = 0.0
    autotune_timed_runs: int = 0  # timed candidate runs spent in warmup
                                  # (0 when the on-disk cache was already hot)
    kernel_fallbacks: dict = field(default_factory=dict)
                                  # "op(shape)" -> count of silent jnp-ref
                                  # fallbacks observed (kernels/ops counters)

    @property
    def slot_utilization(self) -> float:
        """Mean fraction of decode-batch rows doing useful work."""
        return self.active_slot_steps / max(1, self.decode_steps
                                            * max(1, self.max_slots))


class _Slot:
    __slots__ = ("state", "req", "input_tok", "input_x", "input_pos",
                 "tail", "tail_idx", "out", "admit_vtime", "first_tok_vtime",
                 "admitted_with_active")

    def __init__(self):
        self.state = FREE
        self.req = None


class ServeEngine:
    """Continuous-batching engine over an exported serving-params tree.

    cfg/sparams/rt as elsewhere in the repo; ``max_len`` bounds prompt +
    generation when any layer keeps a full (non-ring) cache.  ``top_k`` is
    static for the jitted step (0 = unrestricted); per-request temperature
    is dynamic.  ``policy``: "continuous" (default) or "wave" (lock-step
    gang-scheduling baseline).  ``kernel_mode`` overrides ``rt.kernel_mode``
    (see kernels/ops.KERNEL_MODES) — with packed weights and DAS enabled
    the kernel modes route decode through the fused ``das_ternary_gemm``
    datapath (compacted activations straight against base-3 packed weights)
    on every slab-aligned layer.

    ``kernel_mode="tuned"`` additionally runs an eager autotune warmup at
    construction: every (op, shape) the jitted decode/prefill steps will
    trace is tuned via kernels/autotune (perfmodel-ranked candidates
    confirmed by timed runs) and persisted to the on-disk cache, so a second
    engine over the same shapes constructs with ZERO timed runs
    (``stats.autotune_timed_runs``).  Re-tune (delete the cache file) after
    changing backends — jit traces bake the config chosen at trace time.
    """

    def __init__(self, cfg: ModelConfig, sparams: dict,
                 rt: Runtime = Runtime(), *, max_slots: int = 4,
                 max_len: int = 512, top_k: int = 0, seed: int = 0,
                 policy: str = "continuous", kernel_mode: str | None = None):
        if policy not in ("continuous", "wave"):
            raise ValueError(f"unknown admission policy {policy!r}")
        if kernel_mode is not None:
            rt = replace(rt, kernel_mode=kernel_mode)
        self.cfg, self.sparams, self.rt = cfg, sparams, rt
        self.max_slots, self.max_len = max_slots, max_len
        self.policy = policy
        self.scheduler = FifoScheduler()
        self.stats = EngineStats(max_slots=max_slots)
        self.vtime = 0
        self._uses_embeds = MD.uses_embeds(cfg)
        self._cache_dtype = jnp.dtype(cfg.dtype)
        kinds = cfg.layer_kinds()
        sw = [A.kind_sink_window(cfg, k, rt.serve_sparse) for k in kinds
              if k in ("attn", "local")]
        self._has_full = any(s >= A.FULL_SINK for s, _ in sw)
        self._has_stream = any(s < A.FULL_SINK for s, _ in sw)
        # streaming prefill consumes whole packs; prompts prefill their
        # longest pack-aligned prefix and decode the tail token-by-token
        self._chunk = (cfg.lpsa.chunk if cfg.lpsa else 256) \
            if self._has_stream else 1

        self.caches = MD.init_caches(None, cfg, max_slots, max_len, rt,
                                     self._cache_dtype)
        self._empty1 = MD.init_caches(None, cfg, 1, max_len, rt,
                                      self._cache_dtype)
        self._slots = [_Slot() for _ in range(max_slots)]
        self._results: dict[int, RequestResult] = {}
        self._pending_uids: set[int] = set()
        self._base_key = jax.random.PRNGKey(seed)
        self._sampler = make_sampler(top_k)
        self._top_k = top_k

        if rt.kernel_mode == "tuned":
            self._autotune_warmup()   # eager: must precede any jit trace

        self._prefill = jax.jit(
            lambda sp, x: MD.prefill(sp, cfg, x, rt, max_len=max_len))
        self._step = jax.jit(self._step_fn, donate_argnums=(1,))
        self._insert = jax.jit(self._insert_fn, donate_argnums=(0,))
        self._sample1 = jax.jit(
            lambda lg, uid, temp: sample_token(
                lg, self._fold_key(uid, jnp.int32(0)), temp, top_k))

    def _autotune_warmup(self) -> None:
        """Tune every (op, shape) the serving steps will trace, eagerly.

        GEMM shapes: the standard transformer projection pairs at the decode
        row count (``max_slots``) and the streaming-prefill pack length
        (``self._chunk``).  Attention: one entry per layer-kind
        (sink, window) at the decode cache length.  Shapes that miss at
        trace time (exotic archetypes, odd prefill prefixes) fall back to
        the deterministic perfmodel ranking — same impl family, still zero
        timed runs inside the trace.
        """
        from repro.kernels import autotune
        cfg, tc, rt = self.cfg, self.cfg.ternary, self.rt
        cache = autotune.default_cache()
        before = cache.timed_runs
        das = tc.das if (tc.enabled and tc.das is not None) else None
        pairs = {(cfg.d_model, cfg.q_dim), (cfg.d_model, cfg.kv_dim),
                 (cfg.q_dim, cfg.d_model), (cfg.d_model, cfg.d_ff),
                 (cfg.d_ff, cfg.d_model)}
        for m in sorted({self.max_slots, self._chunk}):
            for k, n in sorted(pairs):
                if das is not None:
                    autotune.tune("das_ternary_gemm", cache=cache, m=m, k=k,
                                  n=n, keep=das.keep, block=das.block)
                else:
                    autotune.tune("ternary_gemm", cache=cache, m=m, k=k, n=n,
                                  keep=0, block=0)
        for kind in set(cfg.layer_kinds()) & {"attn", "local"}:
            sink, window = A.kind_sink_window(cfg, kind, rt.serve_sparse)
            lk = (sink + window) if sink < A.FULL_SINK else self.max_len
            autotune.tune("sparse_attn", cache=cache, **autotune.attn_dims(
                hq=cfg.n_heads, hkv=cfg.n_kv_heads, lq=1, lk=lk,
                d=cfg.head_dim_, sink=sink, window=window))
        self.stats.autotune_timed_runs += cache.timed_runs - before

    # -- jitted pieces ----------------------------------------------------

    def _fold_key(self, uid, counter):
        return jax.random.fold_in(jax.random.fold_in(self._base_key, uid),
                                  counter)

    def _step_fn(self, sparams, caches, tok, t, temps, uids, counters,
                 active, forced, forced_x):
        """One batched decode tick: embed -> decode_step -> sample.

        tok (B,) int32 inputs; t (B,) per-sequence positions; forced/
        forced_x override the input with raw prompt embeddings for
        stub-frontend models still absorbing their prompt tail.
        """
        if self._uses_embeds:
            x = jnp.take(sparams["embed"], tok, axis=0).astype(jnp.float32)
            x = jnp.where(forced[:, None], forced_x, x)[:, None, :]
            logits, caches = MD.decode_step(sparams, self.cfg, caches, x, t,
                                            self.rt)
        else:
            logits, caches = MD.decode_step(sparams, self.cfg, caches, tok, t,
                                            self.rt)
        keys = jax.vmap(self._fold_key)(uids, counters)
        next_tok = self._sampler(logits, keys, temps)
        next_tok = jnp.where(active, next_tok, 0)
        return next_tok, caches

    def _insert_fn(self, big, small, slot):
        """Overwrite one slot's rows with a batch-1 cache pytree."""
        stacked = None
        if big["stacked"] is not None:
            stacked = jax.tree.map(lambda bg, sm: bg.at[:, slot].set(
                sm[:, 0].astype(bg.dtype)), big["stacked"], small["stacked"])
        tail = jax.tree.map(lambda bg, sm: bg.at[slot].set(
            sm[0].astype(bg.dtype)), big["tail"], small["tail"])
        return {"stacked": stacked, "tail": tail}

    # -- public API -------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.prompt_len < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.uid}: max_new_tokens must be >= 1")
        if self._has_full and req.prompt_len + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt {req.prompt_len} + gen "
                f"{req.max_new_tokens} exceeds max_len {self.max_len} "
                f"(a full-cache layer is active)")
        # duplicate uids among in-flight work would collide in the results
        # dict AND share a sampling-key stream (correlated draws)
        in_flight = {s.req.uid for s in self._slots if s.req is not None}
        if req.uid in in_flight or req.uid in self._pending_uids \
                or req.uid in self._results:
            raise ValueError(f"request uid {req.uid} already in flight")
        self._pending_uids.add(req.uid)
        self.scheduler.add(req)

    @property
    def num_active(self) -> int:
        return sum(s.state != FREE for s in self._slots)

    def reset_clock(self) -> None:
        """Zero the virtual clock and stats between traces (caches and jit
        compilation caches survive — use to warm up before a timed replay).
        Only valid when the engine is drained."""
        if self.num_active or self.scheduler:
            raise RuntimeError("reset_clock on a non-drained engine")
        self.vtime = 0
        self.stats = EngineStats(
            max_slots=self.max_slots,
            autotune_timed_runs=self.stats.autotune_timed_runs)

    def timed_replay(self, trace) -> dict[int, RequestResult]:
        """Replay `trace` twice — once to pay the XLA compiles, then timed
        with warm caches — and return the timed run's results (wall-clock
        stats reflect only the second replay)."""
        for r in trace:
            self.submit(r)
        self.run()
        self.reset_clock()
        for r in trace:
            self.submit(r)
        return self.run()

    def run(self) -> dict[int, RequestResult]:
        """Drain the queue; returns uid -> RequestResult."""
        t0 = time.perf_counter()
        while self.scheduler or self.num_active:
            self._admit_ready()
            if not self.num_active:
                nxt = self.scheduler.next_arrival()
                if nxt is None:   # nothing queued, nothing active
                    break
                self.vtime = max(self.vtime, nxt)   # idle fast-forward
                continue
            self.step_decode()
        self.stats.wall_seconds += time.perf_counter() - t0
        # surface silent jnp-reference fallbacks (process-wide counters; a
        # populated dict under a kernel mode means some layer shapes are not
        # slab-aligned and are quietly running the slow reference path)
        self.stats.kernel_fallbacks = {
            f"{op}{key}": cnt for (op, key), cnt in
            ops.fallback_counts().items()}
        out, self._results = self._results, {}
        return out

    # -- admission --------------------------------------------------------

    def _admit_ready(self) -> None:
        if self.policy == "wave" and self.num_active:
            return
        for i, slot in enumerate(self._slots):
            if slot.state != FREE:
                continue
            req = self.scheduler.pop_ready(self.vtime)
            if req is None:
                return
            self._admit(i, req)

    def _admit(self, idx: int, req: Request) -> None:
        slot = self._slots[idx]
        p = req.prompt_len
        prefix = (p // self._chunk) * self._chunk
        self._pending_uids.discard(req.uid)
        # mid-decode admission metric: slots already mid-stream (admitted in
        # an EARLIER tick) — same-tick co-admissions don't count
        slot.admitted_with_active = sum(
            1 for s2 in self._slots
            if s2.state != FREE and s2.admit_vtime < self.vtime)
        slot.req = req
        slot.admit_vtime = self.vtime
        slot.out = []
        slot.input_x = None
        if prefix > 0:
            logits, small = self._prefill(self.sparams,
                                          jnp.asarray(req.prompt)[None, :prefix])
            self.stats.prefill_tokens += prefix
        else:
            logits, small = None, self._empty1
        self.caches = self._insert(self.caches, small, jnp.int32(idx))
        if prefix == p:
            # prompt fully absorbed: first token comes from prefill logits
            tok = int(self._sample1(logits[0], jnp.int32(req.uid),
                                    jnp.float32(req.temperature)))
            slot.state = DECODE
            slot.first_tok_vtime = self.vtime
            slot.out.append(tok)
            slot.input_tok = tok
            slot.input_pos = p
            self.stats.generated_tokens += 1
            if self._finished(slot, tok):
                self._retire(idx)
        else:
            slot.state = PREFILL
            slot.tail = req.prompt[prefix:]
            slot.tail_idx = 1
            slot.input_pos = prefix
            if self._uses_embeds:
                slot.input_tok = 0
                slot.input_x = np.asarray(slot.tail[0], np.float32)
            else:
                slot.input_tok = int(slot.tail[0])

    # -- the decode tick --------------------------------------------------

    def step_decode(self) -> None:
        b = self.max_slots
        tok = np.zeros((b,), np.int32)
        t = np.zeros((b,), np.int32)
        temps = np.zeros((b,), np.float32)
        uids = np.zeros((b,), np.int32)
        counters = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        forced = np.zeros((b,), bool)
        d_model = self.cfg.d_model
        forced_x = np.zeros((b, d_model), np.float32)
        for i, s in enumerate(self._slots):
            if s.state == FREE:
                continue
            active[i] = True
            tok[i] = s.input_tok
            t[i] = s.input_pos
            temps[i] = s.req.temperature
            uids[i] = s.req.uid
            counters[i] = len(s.out)
            if s.input_x is not None:
                forced[i] = True
                forced_x[i] = s.input_x

        next_tok, self.caches = self._step(
            self.sparams, self.caches, jnp.asarray(tok), jnp.asarray(t),
            jnp.asarray(temps), jnp.asarray(uids), jnp.asarray(counters),
            jnp.asarray(active), jnp.asarray(forced), jnp.asarray(forced_x))
        next_tok = np.asarray(next_tok)

        self.stats.decode_steps += 1
        self.stats.active_slot_steps += int(active.sum())
        self.vtime += 1

        for i, s in enumerate(self._slots):
            if s.state == PREFILL:
                if s.tail_idx < len(s.tail):
                    s.input_pos += 1
                    nxt = s.tail[s.tail_idx]
                    if self._uses_embeds:
                        s.input_x = np.asarray(nxt, np.float32)
                    else:
                        s.input_tok = int(nxt)
                    s.tail_idx += 1
                else:
                    # last prompt token went in this tick -> first sample
                    s.state = DECODE
                    s.input_x = None
                    s.first_tok_vtime = self.vtime
                    self._deliver(i, int(next_tok[i]))
            elif s.state == DECODE:
                self._deliver(i, int(next_tok[i]))

    def _deliver(self, idx: int, tok: int) -> None:
        s = self._slots[idx]
        s.out.append(tok)
        s.input_tok = tok
        s.input_pos = s.req.prompt_len + len(s.out) - 1
        self.stats.generated_tokens += 1
        if self._finished(s, tok):
            self._retire(idx)

    def _finished(self, s: _Slot, tok: int) -> bool:
        return (len(s.out) >= s.req.max_new_tokens
                or (s.req.eos_id is not None and tok == s.req.eos_id))

    def _retire(self, idx: int) -> None:
        s = self._slots[idx]
        r = s.req
        self._results[r.uid] = RequestResult(
            uid=r.uid, tokens=np.asarray(s.out, np.int32),
            prompt_len=r.prompt_len, arrival=r.arrival,
            admit_vtime=s.admit_vtime, first_token_vtime=s.first_tok_vtime,
            finish_vtime=self.vtime,
            admitted_with_active=s.admitted_with_active)
        s.state = FREE
        s.req = None
        s.input_x = None
        s.tail = None
