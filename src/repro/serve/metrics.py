"""Live serving telemetry: per-request SLO records + rolling engine gauges.

``Telemetry`` is a sink the engine calls as it serves (attach with
``engine.telemetry = Telemetry(...)`` or ``Telemetry(engine=engine)``):

  * ``on_admit(req, vtime)``   — queue-wait accounting at slot claim
  * ``on_tick(engine, n, dt)`` — once per batched decode step (wall dt)
  * ``on_finish(result, eng)`` — once per retired request
  * ``on_reshard(engine, ...)`` — once per elastic recovery (device loss
    survived: mesh shrink + replay); logs a ``{"type": "reshard", ...}``
    JSONL line with the recovery latency and surviving topology

From those it maintains (a) cumulative counters that must agree with
``EngineStats`` (tokens, requests, preemptions — test-asserted), (b) a
rolling window of recent ticks/requests for live gauges (tok/s over wall
time, slot utilization, TTFT/latency/queue-wait percentiles, SLO
attainment), and (c) an optional JSON-lines export: one ``{"type":
"request", ...}`` line per finished request plus a ``{"type": "tick",
...}`` snapshot line every ``snapshot_every`` ticks — the flight recorder
a long-running server leaves behind.  ``snapshot()`` returns the live
gauge dict the HTTP ``/metrics`` endpoint serves.

Kernel-fallback reporting uses ``engine.kernel_fallback_deltas()`` (the
per-engine baseline), so a telemetry stream never shows another
co-resident engine's fallbacks.

Thread-safety: the engine thread writes, any thread may ``snapshot()`` —
one lock covers the rolling state.
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque

import numpy as np

__all__ = ["Telemetry"]


def _pct(values, q) -> float:
    if not values:
        return 0.0
    return float(np.percentile(np.asarray(values, np.float64), q))


class Telemetry:
    def __init__(self, engine=None, jsonl_path: str | None = None,
                 window: int = 256, snapshot_every: int = 64):
        self._lock = threading.Lock()
        self._t0 = time.time()
        self._window = window
        self._snapshot_every = snapshot_every
        # rolling per-tick records: (wall_dt, active_slots, tokens_delta)
        self._ticks: deque = deque(maxlen=window)
        # rolling finished-request records (dicts, see on_finish)
        self._recent: deque = deque(maxlen=window)
        # cumulative counters (must track EngineStats)
        self.tokens_out = 0
        self.requests_finished = 0
        self.prefill_tokens = 0
        self.queue_wait_steps = 0
        self.slo_tracked = 0
        self.slo_met = 0
        self.preemptions = 0
        self.reshards = 0
        self.recovery_seconds = 0.0
        self.ticks_seen = 0
        self._last_generated = None   # EngineStats.generated_tokens baseline
        self._f = open(jsonl_path, "a") if jsonl_path else None
        if engine is not None:
            self.attach(engine)

    def attach(self, engine) -> "Telemetry":
        engine.telemetry = self
        # token baseline: only tokens generated AFTER attachment count
        self._last_generated = engine.stats.generated_tokens
        return self

    def _sync_tokens_locked(self, engine) -> int:
        """Fold EngineStats.generated_tokens growth into tokens_out; the
        delta covers both per-tick samples and the first tokens sampled at
        admission (prefill logits, outside any tick)."""
        gen = engine.stats.generated_tokens
        if self._last_generated is None:
            self._last_generated = 0
        delta = gen - self._last_generated
        self._last_generated = gen
        self.tokens_out += delta
        return delta

    # -- engine-facing hooks ----------------------------------------------

    def on_admit(self, req, vtime: int) -> None:
        with self._lock:
            self.queue_wait_steps += vtime - req.arrival

    def on_tick(self, engine, n_active: int, wall_dt: float) -> None:
        with self._lock:
            delta = self._sync_tokens_locked(engine)
            self.ticks_seen += 1
            self._ticks.append((wall_dt, n_active, delta))
            due = (self._f is not None
                   and self.ticks_seen % self._snapshot_every == 0)
        if due:
            self._write({"type": "tick", "vtime": engine.vtime,
                         **self._gauges(engine)})

    def on_finish(self, result, engine) -> None:
        rec = {
            "uid": result.uid,
            "prompt_len": result.prompt_len,
            "new_tokens": int(len(result.tokens)),
            "queue_wait_steps": result.queue_wait_steps,
            "ttft_steps": result.ttft_steps,
            "latency_steps": result.latency_steps,
            "slo_steps": result.slo_steps,
            "slo_met": result.slo_met,
            "preempted": result.preempted,
        }
        with self._lock:
            self._sync_tokens_locked(engine)
            self.requests_finished += 1
            self._recent.append(rec)
            if result.preempted:
                self.preemptions += 1
            if result.slo_steps is not None:
                self.slo_tracked += 1
                self.slo_met += int(result.slo_met)
        if self._f is not None:
            self._write({"type": "request", "ts": time.time(), **rec})

    def on_reshard(self, engine, *, lost: int, seconds: float,
                   in_flight: int) -> None:
        topo = getattr(engine, "topology", None)
        with self._lock:
            self.reshards += 1
            self.recovery_seconds += seconds
        if self._f is not None:
            self._write({
                "type": "reshard", "ts": time.time(),
                "vtime": engine.vtime, "lost_devices": lost,
                "recovery_seconds": round(seconds, 6),
                "in_flight_replayed": in_flight,
                "topology": (None if topo is None else
                             {"pods": topo.pods, "dp": topo.dp,
                              "tp": topo.tp}),
            })

    # -- reads ------------------------------------------------------------

    def _gauges(self, engine=None) -> dict:
        """Rolling-window gauges (caller holds no lock; we take it)."""
        with self._lock:
            ticks = list(self._ticks)
            recent = list(self._recent)
            totals = {
                "tokens_out": self.tokens_out,
                "requests_finished": self.requests_finished,
                "queue_wait_steps_total": self.queue_wait_steps,
                "slo_tracked": self.slo_tracked,
                "slo_met": self.slo_met,
                "preemptions": self.preemptions,
                "reshards": self.reshards,
                "recovery_seconds": round(self.recovery_seconds, 6),
                "ticks": self.ticks_seen,
            }
        wall = sum(t[0] for t in ticks)
        toks = sum(t[2] for t in ticks)
        slots = engine.max_slots if engine is not None else 1
        util = (sum(t[1] for t in ticks) / max(1, len(ticks) * slots))
        out = {
            "uptime_s": round(time.time() - self._t0, 3),
            "rolling": {
                "window_ticks": len(ticks),
                "tok_s": toks / wall if wall > 0 else 0.0,
                "slot_utilization": util,
                "ttft_steps_p50": _pct([r["ttft_steps"] for r in recent], 50),
                "ttft_steps_p95": _pct([r["ttft_steps"] for r in recent], 95),
                "latency_steps_p50": _pct(
                    [r["latency_steps"] for r in recent], 50),
                "latency_steps_p95": _pct(
                    [r["latency_steps"] for r in recent], 95),
                "queue_wait_steps_p50": _pct(
                    [r["queue_wait_steps"] for r in recent], 50),
            },
            "totals": totals,
            "slo_attainment": (totals["slo_met"] / totals["slo_tracked"]
                               if totals["slo_tracked"] else None),
        }
        return out

    def snapshot(self, engine=None) -> dict:
        """Live gauge dict (the `/metrics` endpoint body).  With an engine,
        adds its authoritative stats, pool occupancy and per-engine
        kernel-fallback deltas."""
        out = self._gauges(engine)
        if engine is not None:
            st = engine.stats
            out["engine"] = {
                "vtime": engine.vtime,
                "active_slots": engine.num_active,
                "queue_depth": len(engine.scheduler),
                "max_slots": engine.max_slots,
                "decode_steps": st.decode_steps,
                "generated_tokens": st.generated_tokens,
                "prefill_tokens": st.prefill_tokens,
                "slot_utilization": st.slot_utilization,
                "preemptions": st.preemptions,
                "reshards": st.reshards,
                "recovery_seconds": round(st.recovery_seconds, 6),
                "kernel_fallbacks": engine.kernel_fallback_deltas(),
            }
            pool = engine.pool_stats()
            out["pool"] = {k: pool[k] for k in
                           ("layout", "pages_in_use", "pages_peak",
                            "bytes_in_use", "num_pages")}
        return out

    # -- jsonl plumbing ----------------------------------------------------

    def _write(self, obj: dict) -> None:
        line = json.dumps(obj)
        with self._lock:
            if self._f is None:
                return
            self._f.write(line + "\n")
            self._f.flush()

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None
