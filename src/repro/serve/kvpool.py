"""Host-side bookkeeping for the block-paged KV pool.

Two pieces, both pure Python/numpy (they run between jitted decode steps and
never appear inside a trace):

* :class:`PagePool` — refcounted free-list allocator over the device arenas
  created by ``kvcache.CacheSpec(layout="paged")``.  Page 0 is the reserved
  null page (unmapped page-table entries point at it) and is never handed
  out.  A page's refcount is the number of holders: each engine slot whose
  page table maps it counts one, and each radix-trie prefix entry that pins
  it counts one.  ``release`` decrements and returns the pages that dropped
  to zero so the caller can scrub their position maps before reuse.

* :class:`RadixIndex` — a path-compressed radix trie over token-id tuples.
  ``ServeEngine`` registers each freshly prefilled pack-aligned prompt
  prefix here (pages + a host snapshot of the non-paged layer states + the
  prefill logits); admission walks the trie to find (a) the deepest
  *registered* ancestor of a new prompt — reusable exactly, states and all —
  and (b) the longest *common* prefix with any registered sequence, whose
  whole pages are reusable on their own for configs where every layer is
  paged (KV at position i depends only on tokens <= i).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Iterator

import numpy as np

__all__ = ["PagePool", "RadixIndex", "PrefixEntry"]


class PagePool:
    """Refcounted free-list allocator for a paged KV arena.

    Tracks only page *ids* — the device arenas live in the engine's cache
    pytree.  ``num_pages`` includes the reserved null page 0, so the usable
    capacity is ``num_pages - 1``.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("PagePool needs num_pages >= 2 (page 0 is the "
                             "reserved null page)")
        if page_size < 1:
            raise ValueError("PagePool needs page_size >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        self.refs = np.zeros(num_pages, np.int32)
        # LIFO free list keeps recently-freed (cache-warm) pages hot
        self._free: list[int] = list(range(num_pages - 1, 0, -1))
        self.peak_in_use = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - 1 - len(self._free)

    def alloc(self) -> int | None:
        """One fresh page with refcount 1, or None when the pool is empty
        (the caller evicts prefix entries and retries)."""
        if not self._free:
            return None
        p = self._free.pop()
        self.refs[p] = 1
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return p

    def retain(self, pages) -> None:
        for p in pages:
            if p == 0:
                continue
            if self.refs[p] <= 0:
                raise RuntimeError(f"retain of free page {p}")
            self.refs[p] += 1

    def release(self, pages) -> list[int]:
        """Drop one reference per page; -> the pages that became free (the
        caller must scrub their position maps to -1 before reuse)."""
        freed = []
        for p in pages:
            if p == 0:
                continue
            if self.refs[p] <= 0:
                raise RuntimeError(f"release of free page {p}")
            self.refs[p] -= 1
            if self.refs[p] == 0:
                self._free.append(p)
                freed.append(int(p))
        return freed


@dataclass
class PrefixEntry:
    """One cached pack-aligned prompt prefix.

    ``pages`` covers positions [0, length) — ceil(length / page_size) ids,
    the last one possibly partial.  ``state`` is a host (numpy) snapshot of
    the non-paged layer states (ring caches, recurrent states) at position
    ``length``, or None when every layer is paged.  ``logits`` is the
    prefill output at position length-1 (so an exact whole-prompt hit can
    sample its first token bitwise-identically to a fresh prefill).
    """
    length: int
    pages: tuple[int, ...]
    state: Any = None
    logits: np.ndarray | None = None
    last_used: int = 0
    hits: int = 0


class _Node:
    __slots__ = ("edges", "entry")

    def __init__(self):
        # first token -> (label tuple, child); path compression keeps one
        # node per branch point / registered prefix, not one per token
        self.edges: dict[int, tuple[tuple, "_Node"]] = {}
        self.entry: PrefixEntry | None = None


class RadixIndex:
    """Path-compressed radix trie keyed by token-id tuples."""

    def __init__(self):
        self._root = _Node()
        self._count = 0

    def __len__(self) -> int:
        return self._count

    def items(self) -> Iterator[tuple[tuple, PrefixEntry]]:
        stack: list[tuple[tuple, _Node]] = [((), self._root)]
        while stack:
            prefix, node = stack.pop()
            if node.entry is not None:
                yield prefix, node.entry
            for label, child in node.edges.values():
                stack.append((prefix + label, child))

    def insert(self, tokens: tuple, entry: PrefixEntry) -> bool:
        """Register ``entry`` at ``tokens``; False if already present."""
        node = self._root
        i = 0
        while i < len(tokens):
            first = tokens[i]
            if first not in node.edges:
                child = _Node()
                node.edges[first] = (tuple(tokens[i:]), child)
                node = child
                i = len(tokens)
                break
            label, child = node.edges[first]
            m = _common(label, tokens[i:])
            if m == len(label):              # consumed the whole edge
                node, i = child, i + m
                continue
            # split the edge at the divergence point
            mid = _Node()
            mid.edges[label[m]] = (label[m:], child)
            node.edges[first] = (label[:m], mid)
            node, i = mid, i + m
        if node.entry is not None:
            return False
        node.entry = entry
        self._count += 1
        return True

    def remove(self, tokens: tuple) -> PrefixEntry | None:
        """Unregister the entry at exactly ``tokens`` (nodes are left in
        place — they are tiny and may be re-registered)."""
        node = self._walk_exact(tokens)
        if node is None or node.entry is None:
            return None
        entry, node.entry = node.entry, None
        self._count -= 1
        return entry

    def _walk_exact(self, tokens: tuple) -> _Node | None:
        node, i = self._root, 0
        while i < len(tokens):
            edge = node.edges.get(tokens[i])
            if edge is None:
                return None
            label, child = edge
            if tuple(tokens[i:i + len(label)]) != label:
                return None
            node, i = child, i + len(label)
        return node

    def lookup(self, tokens) -> tuple[PrefixEntry | None, PrefixEntry | None, int]:
        """-> (deepest_entry, donor_entry, common_len) for a new prompt.

        ``deepest_entry`` is the deepest registered entry whose tokens are a
        prefix of ``tokens`` (exactly reusable: pages + states + logits).
        ``common_len`` is the longest common prefix of ``tokens`` with ANY
        stored sequence, and ``donor_entry`` is some entry below the match
        point — its pages covering [0, common_len) agree with ``tokens``
        token-for-token, so its *whole* pages inside the common prefix are
        reusable by themselves (page-granularity sharing).
        """
        tokens = tuple(int(t) for t in tokens)
        node, i = self._root, 0
        best: PrefixEntry | None = node.entry
        while i < len(tokens):
            edge = node.edges.get(tokens[i])
            if edge is None:
                break
            label, child = edge
            m = _common(label, tokens[i:])
            i += m
            if m < len(label):               # diverged inside the edge
                node = child                 # donor lives below this edge
                break
            node = child
            if node.entry is not None:
                best = node.entry
        donor = self._any_entry(node)
        return best, donor, i

    def _any_entry(self, node: _Node) -> PrefixEntry | None:
        stack = [node]
        while stack:
            n = stack.pop()
            if n.entry is not None:
                return n.entry
            stack.extend(child for _, child in n.edges.values())
        return None


def _common(a, b) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i
