"""STL — Sparse Ternary LUT core semantics (paper Sec. III-A/B/D, Table I).

The STL core computes a ternary mpGEMM tile via a *zero-aware symmetric
precompute table*: activations are grouped in pairs {a, b} (g = 2); the shared
table holds the four dense partial products {a+b, a-b, a, b}; each ternary
weight pair (w0, w1) decodes into

    GIdx (1b)  — asserted when the whole group is zero (gates the PE),
    DIdx (2b)  — selects one of the four symmetric partial products,
    SIdx (1b)  — mirrors the sign (the "negative half" of the 3^2-1=8 cases).

This module is the *algorithm-level oracle* of that datapath: `stl_matmul_ref`
routes every partial product through (GIdx, DIdx, SIdx) exactly as the PE
pipeline does and must equal a plain matmul bit-for-bit in exact arithmetic —
that identity is what the hypothesis tests pin down.  The gate-level
area/power trade itself does not transfer to TPU (see DESIGN.md §2); its
complexity model (Table I) is reproduced analytically below and consumed by
benchmarks/bench_table1_complexity.py.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "GROUP",
    "StlEncoding",
    "stl_encode",
    "stl_decode_dot",
    "stl_matmul_ref",
    "core_complexity",
]

GROUP = 2  # g — activations per group; fixed to 2 by the PE design


class StlEncoding(NamedTuple):
    """Per weight-group control tuple (paper Fig. 5(b))."""

    gidx: jax.Array  # (G, N) bool   — group-is-all-zero gate
    didx: jax.Array  # (G, N) int32  — 0:a+b 1:a-b 2:a 3:b
    sidx: jax.Array  # (G, N) bool   — sign mirror


# (w0+1)*3 + (w1+1)  ->  (gidx, didx, sidx); table ordered for w in {-1,0,1}^2
#   w pair     dot        enc
#   (-1,-1)  -(a+b)   (0, 0, 1)
#   (-1, 0)  -a       (0, 2, 1)
#   (-1, 1)  -(a-b)   (0, 1, 1)
#   ( 0,-1)  -b       (0, 3, 1)
#   ( 0, 0)   0       (1, 0, 0)
#   ( 0, 1)   b       (0, 3, 0)
#   ( 1,-1)   a-b     (0, 1, 0)
#   ( 1, 0)   a       (0, 2, 0)
#   ( 1, 1)   a+b     (0, 0, 0)
_GIDX = jnp.array([0, 0, 0, 0, 1, 0, 0, 0, 0], dtype=jnp.bool_)
_DIDX = jnp.array([0, 2, 1, 3, 0, 3, 1, 2, 0], dtype=jnp.int32)
_SIDX = jnp.array([1, 1, 1, 1, 0, 0, 0, 0, 0], dtype=jnp.bool_)


def stl_encode(w: jax.Array) -> StlEncoding:
    """Encode ternary weights (K, N) int8 into per-group (GIdx, DIdx, SIdx).

    K must be even (groups of 2 along K).
    """
    k, n = w.shape
    if k % GROUP != 0:
        raise ValueError(f"K={k} must be a multiple of the STL group size {GROUP}")
    wp = w.astype(jnp.int32).reshape(k // GROUP, GROUP, n)
    code = (wp[:, 0] + 1) * 3 + (wp[:, 1] + 1)  # (G, N) in [0, 9)
    return StlEncoding(gidx=_GIDX[code], didx=_DIDX[code], sidx=_SIDX[code])


def _precompute_table(x: jax.Array) -> jax.Array:
    """Shared mirror-half precompute table for grouped activations.

    x: (..., K) -> table (..., G, 4) holding [a+b, a-b, a, b] per group.
    One adder ("mirror-half pre-compute adder logic") per group builds it;
    the negative mirrors come from SIdx, never stored (the zero-aware trick).
    """
    g = x.shape[-1] // GROUP
    xg = x.reshape(x.shape[:-1] + (g, GROUP))
    a, b = xg[..., 0], xg[..., 1]
    return jnp.stack([a + b, a - b, a, b], axis=-1)


def stl_decode_dot(x: jax.Array, enc: StlEncoding) -> jax.Array:
    """Compute x @ W via the STL pipeline: table lookup -> sign -> zero gate.

    x: (..., K) float; enc encodes W (K, N).  Returns (..., N).
    """
    table = _precompute_table(x)  # (..., G, 4)
    # lookup: DIdx steers the 4:1 mux per (group, out-channel); expressed as a
    # one-hot select so it stays exact and vectorizes on any backend.
    onehot = jax.nn.one_hot(enc.didx, 4, dtype=table.dtype)  # (G, N, 4)
    sel = jnp.einsum("...gf,gnf->...gn", table, onehot)      # (..., G, N)
    signed = jnp.where(enc.sidx, -sel, sel)         # SIdx mirror
    gated = jnp.where(enc.gidx, 0.0, signed)        # GIdx zero gate
    return jnp.sum(gated, axis=-2)                  # adder tree over G


def stl_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Full STL-core mpGEMM oracle; equals x @ w exactly (float arithmetic)."""
    enc = stl_encode(w.astype(jnp.int8))
    return stl_decode_dot(x, enc)


# --------------------------------------------------------------------------
# Table I — compute-core complexity model (units: primitive ops / table slots)
# --------------------------------------------------------------------------

def core_complexity(core: str, *, n_t: int, g_total: int, g: int = GROUP,
                    s_a: float = 1.0) -> dict[str, float]:
    """Complexity terms of the four A8W1.58 core designs (paper Table I).

    Parameters mirror the paper: N_t output channels, G = K_t/g groups,
    group size g, activation density S_a (<1 only for STL).
    Returns dict with precompute / lookup / adder costs.
    """
    G = float(g_total)
    if core == "add_only":
        return {"precompute": 0.0, "lookup": 0.0, "adder": n_t * G * g}
    if core == "general_lut":  # bit-serial INT2 (2 one-bit planes)
        return {"precompute": G * (2 ** g) * g / n_t,
                "lookup": 2 * n_t * G * (2 ** g),
                "adder": n_t * (G + g)}
    if core == "ternary_lut":  # base-3 element-wise table
        return {"precompute": G * (3 ** g) * g / n_t,
                "lookup": n_t * G * (3 ** g),
                "adder": n_t * G}
    if core == "stl":          # ours: symmetric zero-aware table + DAS
        return {"precompute": s_a * G * (2 ** g) * g / n_t,
                "lookup": s_a * n_t * G * (2 ** g),
                "adder": s_a * n_t * G}
    raise ValueError(f"unknown core {core!r}")
