"""DAS — Dynamic Activation N:M Sparsity (paper Sec. III-C, Fig. 6).

Per token, the hidden dimension is split into blocks of size ``B_s`` (=32 in
the paper); inside each block the Top-K largest-|x| activations survive
(K = S_a * B_s, S_a = 1/2 by default).  The resulting bitmask M both zeroes
the dropped activations and — in hardware — steers a butterfly router that
skips the matching weight channels, shrinking the effective GEMM K-dim by S_a.

     Y = (Q_int8(X) .* M) @ Q_1.58(W)^T ,   M = TopK_block(|X|)      (Eq. 1)

TPU realization: the mask is computed by a vectorized per-block top-k; the
"butterfly" becomes a block-structured gather that *compacts* both the
activations and the ternary weight rows to dense (S_a*K)-long tiles before the
MXU matmul (kernels/das_gemm.py).  This module holds the pure-JAX semantics:

  * ``das_mask``      — the N:M bitmask (ASM in the paper),
  * ``das_apply``     — masked activations (training / QAT path),
  * ``das_compact``   — mask -> compacted activations + absolute lane indices
                        (the serving path the kernels consume),
  * ``das_gemm_ref``  — compacted sparse GEMM oracle.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "DEFAULT_BLOCK",
    "das_mask",
    "das_apply",
    "das_compact",
    "das_gemm_ref",
    "CompactActivation",
]

DEFAULT_BLOCK = 32


class CompactActivation(NamedTuple):
    """Block-compacted activation: values + absolute K-lane indices."""

    values: jax.Array   # (..., K*S_a)
    indices: jax.Array  # (..., K*S_a) int32 lane ids into the original K
    keep_per_block: int
    block_size: int


def _check(k: int, block_size: int, keep: int) -> None:
    if k % block_size != 0:
        raise ValueError(f"hidden dim {k} not divisible by DAS block {block_size}")
    if not (0 < keep <= block_size):
        raise ValueError(f"keep={keep} out of range for block {block_size}")


def das_mask(x: jax.Array, *, block_size: int = DEFAULT_BLOCK,
             keep: int | None = None, sparsity: float = 0.5) -> jax.Array:
    """Top-K-per-block bitmask over |x| along the last axis (the paper's ASM).

    ``keep`` lanes per ``block_size`` survive; default keep = S_a * B_s with
    S_a = 1 - ``sparsity``... nb: the paper calls S_a the *valid* proportion,
    so S_a = keep/block_size and ``sparsity`` = 1 - S_a.
    """
    k = x.shape[-1]
    if keep is None:
        keep = max(1, int(round(block_size * (1.0 - sparsity))))
    if not (0 < keep <= block_size):
        raise ValueError(f"keep={keep} out of range for block {block_size}")
    rem = k % block_size
    if rem:  # non-divisible hidden dims (e.g. bitnet-1.3b d_ff=5460):
        # sparsify the divisible prefix, keep the tail lanes dense
        main = das_mask(x[..., :k - rem], block_size=block_size, keep=keep)
        tail = jnp.ones_like(x[..., k - rem:], dtype=bool)
        return jnp.concatenate([main, tail], axis=-1)
    nb = k // block_size
    xb = jnp.abs(x).reshape(x.shape[:-1] + (nb, block_size))
    # Rank-comparison form (no sort): lane survives iff
    #   #{|x_j| > |x_i|} + #{j < i : |x_j| == |x_i|} < keep.
    # O(B^2)=32x32 compares — pure elementwise/reduce ops, which GSPMD
    # partitions cleanly (lax.top_k lowers to sort, which XLA SPMD
    # *fully replicates*: a 22 GiB all-gather per mask at pod scale).
    ai = xb[..., :, None]
    aj = xb[..., None, :]
    gt = jnp.sum((aj > ai), axis=-1)
    lane = jnp.arange(block_size)
    jlt = (lane[None, :] < lane[:, None])
    eq_before = jnp.sum((aj == ai) & jlt, axis=-1)
    mask = (gt + eq_before) < keep
    return mask.reshape(x.shape)


def das_apply(x: jax.Array, mask: jax.Array) -> jax.Array:
    """Masked activations.  Gradient flows through surviving lanes only
    (mask treated as constant — the paper's sparsify-then-quantize QAT)."""
    return x * mask.astype(x.dtype)


@partial(jax.jit, static_argnames=("block_size", "keep"))
def das_compact(x: jax.Array, *, block_size: int = DEFAULT_BLOCK,
                keep: int = DEFAULT_BLOCK // 2) -> CompactActivation:
    """Compact the Top-K lanes of every block (the butterfly-router output).

    Returns values (..., nb*keep) and absolute lane indices; indices within a
    block are ascending, so the downstream weight gather is quasi-contiguous.
    """
    k = x.shape[-1]
    _check(k, block_size, keep)
    nb = k // block_size
    xb = x.reshape(x.shape[:-1] + (nb, block_size))
    _, idx = jax.lax.top_k(jnp.abs(xb), keep)      # (..., nb, keep)
    idx = jnp.sort(idx, axis=-1)
    vals = jnp.take_along_axis(xb, idx, axis=-1)   # (..., nb, keep)
    base = (jnp.arange(nb, dtype=jnp.int32) * block_size)[:, None]
    abs_idx = idx.astype(jnp.int32) + base          # absolute lane ids
    newshape = x.shape[:-1] + (nb * keep,)
    return CompactActivation(values=vals.reshape(newshape),
                             indices=abs_idx.reshape(newshape),
                             keep_per_block=keep, block_size=block_size)


def das_gemm_ref(ca: CompactActivation, w: jax.Array) -> jax.Array:
    """Oracle sparse GEMM: gather W rows at the kept lanes, dense matmul.

    ``w`` is (K, N).  For batched activations the gather is per token —
    exactly what the butterfly router materializes per cycle in the paper.
    """
    gathered = jnp.take(w, ca.indices, axis=0)       # (..., Kc, N)
    return jnp.einsum("...k,...kn->...n", ca.values.astype(jnp.float32),
                      gathered.astype(jnp.float32))
