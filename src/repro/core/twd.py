"""TWD — LUT-based 64B:80B Ternary Weight Decompression (paper Sec. III-E).

Each ternary value carries log2(3) = 1.585 bits of information; five trits fit
in one byte (3^5 = 243 <= 256), i.e. 1.6 bits/weight.  The paper stores weights
in this base-3 packed form in DRAM and decompresses them with a LUT ROM inside
the memory interface: 64 compressed bytes expand to 80 bytes of 2-bit-packed
weights (320 trits).

On TPU the "ROM" is a VMEM-resident (256, 5) int8 decode table and the
"decompressor" is a vectorized gather executed next to the MXU (see
kernels/ternary_gemm.py for the fused version).  This module provides:

  * offline packing (numpy/JAX) used when exporting checkpoints for serving,
  * the decode LUT constant,
  * pure-JAX decode (the oracle for the Pallas kernels),
  * helpers mapping between logical weight shapes and packed shapes.

Packing is along the *first* (input/K) axis so that a TP-sharded output axis
never splits a packed byte, and K stays contiguous for decode-then-matmul.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

__all__ = [
    "TRITS_PER_BYTE",
    "decode_lut",
    "pack_ternary",
    "unpack_ternary",
    "packed_dim",
    "packed_nbytes",
    "compression_ratio_vs_int2",
]

TRITS_PER_BYTE = 5
_POW3 = np.array([1, 3, 9, 27, 81], dtype=np.int32)  # 3^0 .. 3^4


def _build_decode_lut() -> np.ndarray:
    """(256, 5) int8 table: byte value -> 5 trits in {-1, 0, +1}.

    Entries >= 243 are invalid encodings; they decode to all-zeros (a packed
    stream produced by pack_ternary never contains them).
    """
    lut = np.zeros((256, TRITS_PER_BYTE), dtype=np.int8)
    for byte in range(3 ** TRITS_PER_BYTE):
        v = byte
        for i in range(TRITS_PER_BYTE):
            lut[byte, i] = (v % 3) - 1  # digit in {0,1,2} -> {-1,0,+1}
            v //= 3
    return lut


_DECODE_LUT_NP = _build_decode_lut()


def decode_lut() -> jax.Array:
    """The (256, 5) int8 decode table (paper's dual-port ROM contents)."""
    return jnp.asarray(_DECODE_LUT_NP)


def packed_dim(k: int) -> int:
    """Packed length of a K-sized axis (ceil division by 5)."""
    return (k + TRITS_PER_BYTE - 1) // TRITS_PER_BYTE


def packed_nbytes(shape: tuple[int, ...]) -> int:
    """Total bytes of the packed representation of a (K, ...) weight."""
    k = shape[0]
    rest = int(np.prod(shape[1:])) if len(shape) > 1 else 1
    return packed_dim(k) * rest


def pack_ternary(values: jax.Array | np.ndarray,
                 row_align: int = 1) -> jax.Array:
    """Pack int8 trits in {-1,0,1} along axis 0 into uint8, 5 per byte.

    (K, ...) -> (ceil(K/5), ...) rounded up so the packed row count is a
    multiple of `row_align` (16 at export => packed rows shard 16-way).
    K is zero-padded.
    """
    v = jnp.asarray(values, dtype=jnp.int32)
    k = v.shape[0]
    rows = -(-packed_dim(k) // row_align) * row_align
    kp = rows * TRITS_PER_BYTE
    if kp != k:
        pad = [(0, kp - k)] + [(0, 0)] * (v.ndim - 1)
        v = jnp.pad(v, pad)
    digits = v + 1  # {-1,0,1} -> {0,1,2}
    d = digits.reshape((kp // TRITS_PER_BYTE, TRITS_PER_BYTE) + v.shape[1:])
    pow3 = jnp.asarray(_POW3).reshape((1, TRITS_PER_BYTE) + (1,) * (v.ndim - 1))
    packed = jnp.sum(d * pow3, axis=1)
    return packed.astype(jnp.uint8)


def unpack_ternary(packed: jax.Array, k: int) -> jax.Array:
    """Decode uint8 base-3 bytes back to int8 trits along axis 0.

    (P, ...) -> (k, ...) with k <= 5*P.  Pure-JAX oracle for the Pallas decode;
    implemented as the same LUT gather the hardware ROM performs.
    """
    lut = decode_lut()  # (256, 5)
    trits = lut[packed.astype(jnp.int32)]  # (P, ..., 5)
    # Move the trit digit axis next to P and flatten: (P, 5, ...) -> (5P, ...)
    trits = jnp.moveaxis(trits, -1, 1)
    flat = trits.reshape((packed.shape[0] * TRITS_PER_BYTE,) + packed.shape[1:])
    return flat[:k].astype(jnp.int8)


def unpack_ternary_arith(packed: jax.Array, k: int) -> jax.Array:
    """Arithmetic (gather-free) decode: repeated div/mod by 3.

    Identical output to :func:`unpack_ternary`; preferred inside Pallas TPU
    kernels where a 256-entry gather is slower than 5 cheap integer ops.
    """
    p = packed.astype(jnp.int32)
    outs = []
    for _ in range(TRITS_PER_BYTE):
        outs.append((p % 3) - 1)
        p = p // 3
    trits = jnp.stack(outs, axis=1)  # (P, 5, ...)
    flat = trits.reshape((packed.shape[0] * TRITS_PER_BYTE,) + packed.shape[1:])
    return flat[:k].astype(jnp.int8)


def compression_ratio_vs_int2(k: int) -> float:
    """Bytes(base-3 packed) / Bytes(2-bit packed) for a K-length column.

    The paper's headline: 64B:80B = 0.8 (Sec. III-E).
    """
    b_base3 = packed_dim(k)
    b_int2 = (k + 3) // 4
    return b_base3 / b_int2
