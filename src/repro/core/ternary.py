"""Ternary (1.58-bit) quantization — the substrate of BitNet-style linears.

Implements the paper's quantization functions (Sec. III-C, Eq. 1):

  * ``Q_1.58(W)``  — absmean ternary weight quantization: W -> {-1, 0, +1} * scale,
    with the BitNet b1.58 rule  W_t = round_clip(W / mean(|W|), -1, 1).
  * ``Q_int8(X)``  — per-token absmax int8 activation quantization.
  * Straight-through estimators (STE) for both, so Sparse-BitNet models can be
    trained / fine-tuned exactly as the paper does ("sparsify-then-quantize").

All functions are pure JAX and shard transparently under pjit/shard_map.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "TernaryWeight",
    "absmean_scale",
    "ternary_quantize",
    "ternary_dequantize",
    "ternary_fake_quant",
    "ternary_fake_quant_stacked",
    "int8_quantize",
    "int8_dequantize",
    "int8_fake_quant",
    "QuantizedActivation",
]

EPS = 1e-6


class TernaryWeight(NamedTuple):
    """A ternary-quantized weight: int8 values in {-1, 0, +1} plus a scale.

    ``values`` has the original weight shape; ``scale`` broadcasts against it
    (per-tensor by default, per-output-channel optionally).
    """

    values: jax.Array  # int8, in {-1, 0, 1}
    scale: jax.Array   # f32, broadcastable to ``values``


class QuantizedActivation(NamedTuple):
    values: jax.Array  # int8
    scale: jax.Array   # f32 per-token (…, 1)


def absmean_scale(w: jax.Array, *, per_channel: bool = False) -> jax.Array:
    """BitNet-b1.58 scale: gamma = mean(|W|) (per tensor or per output column)."""
    if per_channel:
        # weights are (in, out): scale per output channel
        return jnp.mean(jnp.abs(w), axis=tuple(range(w.ndim - 1)), keepdims=True) + EPS
    return jnp.mean(jnp.abs(w)) + EPS


def ternary_quantize(w: jax.Array, *, per_channel: bool = False) -> TernaryWeight:
    """W -> TernaryWeight with values = round_clip(W/gamma, -1, 1)."""
    gamma = absmean_scale(w, per_channel=per_channel)
    q = jnp.clip(jnp.round(w / gamma), -1.0, 1.0)
    return TernaryWeight(values=q.astype(jnp.int8), scale=gamma.astype(jnp.float32))


def ternary_dequantize(tw: TernaryWeight, dtype=jnp.float32) -> jax.Array:
    return tw.values.astype(dtype) * tw.scale.astype(dtype)


@jax.custom_vjp
def ternary_fake_quant(w: jax.Array) -> jax.Array:
    """Differentiable (STE) ternary fake-quant used during QAT / fine-tuning.

    Forward: dequantize(quantize(w)).  Backward: identity (straight-through).
    """
    tw = ternary_quantize(w)
    return ternary_dequantize(tw, dtype=w.dtype)


def _tfq_fwd(w):
    return ternary_fake_quant(w), None


def _tfq_bwd(_, g):
    return (g,)


ternary_fake_quant.defvjp(_tfq_fwd, _tfq_bwd)


def int8_quantize(x: jax.Array, *, axis: int = -1) -> QuantizedActivation:
    """Per-token absmax int8 quantization of activations (paper's Q_int8)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = (amax / 127.0 + EPS).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return QuantizedActivation(values=q, scale=scale)


def int8_dequantize(qa: QuantizedActivation, dtype=jnp.float32) -> jax.Array:
    return qa.values.astype(dtype) * qa.scale.astype(dtype)


@jax.custom_vjp
def int8_fake_quant(x: jax.Array) -> jax.Array:
    qa = int8_quantize(x)
    return int8_dequantize(qa, dtype=x.dtype)


def _i8fq_fwd(x):
    return int8_fake_quant(x), None


def _i8fq_bwd(_, g):
    return (g,)


int8_fake_quant.defvjp(_i8fq_fwd, _i8fq_bwd)


@jax.custom_vjp
def ternary_fake_quant_stacked(w: jax.Array) -> jax.Array:
    """STE fake-quant with a per-leading-axis (per-expert) absmean scale.

    Shard-invariant under expert parallelism: each expert's scale depends
    only on its own slab, so local computation inside shard_map equals the
    global computation exactly (a per-tensor scale would differ per shard).
    """
    axes = tuple(range(1, w.ndim))
    gamma = jnp.mean(jnp.abs(w), axis=axes, keepdims=True) + EPS
    q = jnp.clip(jnp.round(w / gamma), -1.0, 1.0)
    return (q * gamma).astype(w.dtype)


def _tfqs_fwd(w):
    return ternary_fake_quant_stacked(w), None


def _tfqs_bwd(_, g):
    return (g,)


ternary_fake_quant_stacked.defvjp(_tfqs_fwd, _tfqs_bwd)


@partial(jax.jit, static_argnames=("out_dtype",))
def ternary_matmul_ref(x: jax.Array, tw_values: jax.Array, tw_scale: jax.Array,
                       out_dtype=jnp.float32) -> jax.Array:
    """Reference ternary mpGEMM: int8/f32 activation x {-1,0,1} weight.

    Computes x @ (values * scale).  The MXU-friendly formulation keeps the
    matmul in the input dtype (int8 inputs use int32 accumulation upstream in
    kernels/); this reference stays in float for clarity.
    """
    w = tw_values.astype(out_dtype) * tw_scale.astype(out_dtype)
    return jnp.matmul(x.astype(out_dtype), w)
