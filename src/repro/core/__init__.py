"""repro.core — the paper's contributions as composable JAX modules.

  ternary  : Q_1.58 / Q_int8 quantizers + STE training path
  twd      : base-3 5-trits/byte weight compression + LUT decode
  das      : dynamic activation N:M sparsity (TopK per block)
  stl      : STL-core LUT semantics oracle + Table-I complexity model
  lpsa     : linear-projection-aware sparse attention dataflow
  ipj      : intelligence-per-joule metric
  perfmodel: analytic roofline/power model (paper HW + TPU)
  dse      : design-space exploration (Eq. 4-7)
"""

from . import das, dse, ipj, lpsa, perfmodel, stl, ternary, twd  # noqa: F401
