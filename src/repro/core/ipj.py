"""IPJ — Intelligence Per Joule (paper Sec. I).

    IPJ = #tokens / (perplexity * Joule) = (tokens/s) / (perplexity * Watt)

1/PPL is the average per-token likelihood, so IPJ reads as "expected correct
tokens per Joule".  Used by the DSE objective and the Fig-1/2 benchmarks.
"""

from __future__ import annotations

__all__ = ["ipj", "ipj_from_latency"]


def ipj(tokens_per_s: float, perplexity: float, watts: float) -> float:
    if perplexity <= 0 or watts <= 0:
        raise ValueError("perplexity and watts must be positive")
    return tokens_per_s / (perplexity * watts)


def ipj_from_latency(num_tokens: int, latency_s: float, perplexity: float,
                     watts: float) -> float:
    """IPJ of a whole request: num_tokens generated in latency_s at watts."""
    if latency_s <= 0:
        raise ValueError("latency must be positive")
    return ipj(num_tokens / latency_s, perplexity, watts)
