"""Design Space Exploration (paper Sec. IV-D, Eqs. 4-7).

Grid search minimizing  L(C) = PPL * L_power * L_latency  over the
hyper-parameter vector C = (P_L, P_H, TL_SA [, S_a]) subject to the
pipeline-hiding constraint  P_L / P_H < D_m / TL_SA  (Eq. 7) — the STL-core
latency for a Q projection must cover the HP-core latency for the sparse
QK^T row so attention stays hidden (Fig 10c).

The PPL term interpolates the paper's ablation measurements (Fig 11 /
Tables II-III); the power term follows Eq. 5 with per-core and KV-buffer
power coefficients calibrated against Table IV; latency follows the
perfmodel roofline (Eq. 6).

A TPU-facing variant swaps (P_L, P_H) for (pack size C, TL_SA, S_a): on a
single-chip temporal pipeline the constraint becomes a roofline-balance
condition  t_attn(C, TL_SA) <= t_proj(C, S_a).
"""

from __future__ import annotations

from dataclasses import dataclass
from itertools import product
from typing import Iterable

import numpy as np

from .perfmodel import (HardwareSpec, ModelShape, TenetOpt, stage_cost)

__all__ = [
    "PPL_TABLE", "ppl_model", "DseCandidate", "dse_grid_search",
    "tpu_dse_grid_search",
]

# --- measured algorithm points (paper Tables II/III, Fig 11) ---------------
# (model, S_a)   -> wikitext2/c4 PPL.  S_a = 1.0 means dense BitNet.
PPL_TABLE = {
    ("bitnet-1.3b", 1.00): 11.27,
    ("bitnet-1.3b", 0.50): 11.32,
    ("bitnet-1.3b", 0.375): 11.90,
    ("bitnet-1.3b", 0.25): 13.40,   # Fig 11: sharp knee at S_a = 3/4 dropped
    ("bitnet-3b", 1.00): 9.71,
    ("bitnet-3b", 0.50): 9.90,
    ("bitnet-3b", 0.375): 10.30,
    ("bitnet-3b", 0.25): 11.10,
}
# TL_SA sensitivity (Fig 11 right): marginal 512 -> 1536.
TLSA_PPL_DELTA = {512: +0.12, 768: +0.05, 1024: 0.0, 1280: -0.02, 1536: -0.03}


def ppl_model(model_name: str, s_a: float, tl_sa: int) -> float:
    """Interpolated PPL(S_a, TL_SA) from the paper's ablation data."""
    pts = sorted((sa, p) for (m, sa), p in PPL_TABLE.items() if m == model_name)
    if not pts:
        raise KeyError(f"no PPL data for {model_name}")
    xs = np.array([p[0] for p in pts])
    ys = np.array([p[1] for p in pts])
    base = float(np.interp(s_a, xs, ys))
    ks = sorted(TLSA_PPL_DELTA)
    dl = float(np.interp(tl_sa, ks, [TLSA_PPL_DELTA[k] for k in ks]))
    return base + dl


# --- hardware-side models (Eq. 5 coefficients calibrated to Table IV) ------
P_STL_CORE_W = 0.672 / 16    # 672 mW for 16 cores
P_HP_CORE_W = 3.3152 / 4     # 3315.2 mW for 4 cores
P_KV_BUF_W_PER_KB = 0.4017 / 1408  # buffer power scales ~linearly with KB
P_CONST_W = 0.6379 + 0.2248 + 0.3919 + 0.0262 + 0.0201  # SNU+SFU+TMI+misc
CORE_TOPS = 32 * 64 * 2 * 0.5e-3   # TOPS per 32x64 core @ 500 MHz = 2.048


@dataclass(frozen=True)
class DseCandidate:
    p_l: int
    p_h: int
    tl_sa: int
    s_a: float
    ppl: float
    power_w: float
    latency_s: float
    objective: float
    feasible: bool


def _candidate(m: ModelShape, model_name: str, p_l: int, p_h: int,
               tl_sa: int, s_a: float, decode_tl: int) -> DseCandidate:
    ppl = ppl_model(model_name, s_a, tl_sa)
    kv_kb = tl_sa * m.n_kv_heads * m.head_dim * 2 * 2 / 1024  # K+V fp16
    power = (p_l * P_STL_CORE_W + p_h * P_HP_CORE_W
             + kv_kb * P_KV_BUF_W_PER_KB + P_CONST_W)
    hw = HardwareSpec("dse", CORE_TOPS * p_l, CORE_TOPS * p_h, 512.0, power,
                      onchip_mb=1.4)
    opt = TenetOpt(weight_bits=1.6, das=s_a < 1.0, s_a=s_a, lpsa=True,
                   tl_sa=tl_sa)
    c = stage_cost(m, "decode", decode_tl, opt)
    t_low = c.flops_low / (hw.peak_tops_low * 1e12)
    t_high = c.flops_high / (hw.peak_tops_high * 1e12)
    t_mem = c.bytes / (hw.hbm_gbps * 1e9)
    latency = max(t_low, t_high, t_mem)
    feasible = (p_l / p_h) < (m.d_model / tl_sa)          # Eq. 7
    objective = ppl * power * latency
    return DseCandidate(p_l, p_h, tl_sa, s_a, ppl, power, latency, objective,
                        feasible)


def dse_grid_search(m: ModelShape, model_name: str, *,
                    p_l_grid: Iterable[int] = (8, 12, 16, 24, 32),
                    p_h_grid: Iterable[int] = (2, 4, 6, 8),
                    tl_sa_grid: Iterable[int] = (512, 1024, 1536),
                    s_a_grid: Iterable[float] = (1.0, 0.5, 0.25),
                    decode_tl: int = 2048) -> list[DseCandidate]:
    """Paper's DSE: returns feasible candidates sorted by objective (Eq. 4)."""
    out = [
        _candidate(m, model_name, pl, ph, tl, sa, decode_tl)
        for pl, ph, tl, sa in product(p_l_grid, p_h_grid, tl_sa_grid, s_a_grid)
    ]
    feas = [c for c in out if c.feasible]
    return sorted(feas, key=lambda c: c.objective)


# --------------------------------------------------------------------------
# TPU variant: pick (chunk C, TL_SA, S_a) so attention hides under projection
# --------------------------------------------------------------------------

def tpu_dse_grid_search(m: ModelShape, model_name: str, hw: HardwareSpec, *,
                        chunk_grid: Iterable[int] = (128, 256, 512),
                        tl_sa_grid: Iterable[int] = (512, 1024, 1536),
                        s_a_grid: Iterable[float] = (1.0, 0.5),
                        ) -> list[dict]:
    """Balance t_attn(C, TL_SA) vs t_proj(C, S_a) on one TPU chip.

    Returns dicts sorted by PPL * latency-per-token (power is constant on a
    fixed chip, so Eq. 4 degenerates to PPL * latency).
    """
    d, res = m.d_model, []
    lin_per_tok = 2.0 * m.linear_params()
    for c, tl_sa, s_a in product(chunk_grid, tl_sa_grid, s_a_grid):
        t_proj = lin_per_tok * s_a / (hw.peak_tops_low * 1e12)
        att_ops = 2.0 * 2.0 * m.n_heads * m.head_dim * tl_sa * m.n_layers
        t_attn = att_ops / (hw.peak_tops_high * 1e12)
        hidden = t_attn <= t_proj
        ppl = ppl_model(model_name, s_a, tl_sa)
        lat = max(t_proj, t_attn)
        res.append(dict(chunk=c, tl_sa=tl_sa, s_a=s_a, ppl=ppl,
                        t_proj=t_proj, t_attn=t_attn, hidden=hidden,
                        objective=ppl * lat))
    return sorted(res, key=lambda r: r["objective"])
