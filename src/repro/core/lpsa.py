"""LPSA — Linear-Projection-aware Sparse Attention dataflow (paper Sec. IV-B).

The paper's Algorithm 1: the sequence is split into N packs of C tokens; per
pack, the ternary QKV projections produce K/Q/V which are *immediately*
consumed by sparse attention (attention sink + local window, StreamingLLM
pattern), so attention intermediates never travel to DRAM.  Only the sink KV
(s fixed tokens at sequence start) and a rolling window KV (last w tokens)
stay resident on chip.  TL_SA = s + w valid KV pairs per query row.

TPU mapping: "on-chip KV buffer" = carried scan state that XLA keeps in HBM
but whose *attention working set* per pack is O(C·(s+w)) in VMEM — the same
asymptotic traffic win (sequence activations are read once, attention scores
never materialize globally).  The pack loop is a `lax.scan`, the projections
are the caller-supplied ternary ops (so DAS/TWD compose), and the per-pack
attention is a masked flash-style softmax (Pallas kernel in kernels/ for the
hot path; this file is the exact oracle + dataflow).

Semantics (position p_q attends p_k)  <=>  p_k <= p_q  AND
                                           (p_k < sink  OR  p_q - p_k < window)
(i.e. `window` counts the current token: TL_SA = sink + window slots exactly,
so the decode ring never evicts a still-visible key).
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "LpsaSpec",
    "lpsa_allowed",
    "lpsa_mask",
    "masked_attention_ref",
    "lpsa_prefill",
    "decode_slot",
    "lpsa_decode_attend",
]

NEG_INF = -1e30


class LpsaSpec(NamedTuple):
    sink: int = 128      # attention-sink tokens kept from sequence start
    window: int = 896    # local window (TL_SA = sink + window = 1024, paper)
    chunk: int = 256     # pack size C

    @property
    def tl_sa(self) -> int:
        return self.sink + self.window


def lpsa_allowed(q_pos: jax.Array, k_pos: jax.Array, sink: int, window: int) -> jax.Array:
    """Boolean attend-permission for broadcastable position arrays."""
    causal = k_pos <= q_pos
    keep = (k_pos < sink) | (q_pos - k_pos < window)
    return causal & keep


def lpsa_mask(tl: int, sink: int, window: int) -> jax.Array:
    """Dense (TL, TL) mask — the oracle pattern (diagonal band + sink column)."""
    pos = jnp.arange(tl)
    return lpsa_allowed(pos[:, None], pos[None, :], sink, window)


def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """(B, L, Hkv, D) -> (B, L, Hkv*n_rep, D) for GQA."""
    if n_rep == 1:
        return x
    b, l, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, l, h, n_rep, d)).reshape(b, l, h * n_rep, d)


def _softmax_attend(q, k, v, mask, *, softcap: float | None = None,
                    scale: float | None = None) -> jax.Array:
    """Masked attention oracle.  q:(B,Lq,H,D) k,v:(B,Lk,H,D) mask:(…,Lq,Lk)."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (can't happen for causal q>=0, but keep it safe)
    p = jnp.where(jnp.any(mask, axis=-1, keepdims=True), p, 0.0)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v).astype(q.dtype)


def masked_attention_ref(q, k, v, *, sink: int, window: int,
                         softcap: float | None = None) -> jax.Array:
    """Quadratic LPSA oracle over full sequences (used for training & tests).

    q: (B, L, Hq, D); k, v: (B, L, Hkv, D) with Hq % Hkv == 0.
    """
    b, l, hq, d = q.shape
    hkv = k.shape[2]
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)
    mask = lpsa_mask(l, sink, window)[None, None]  # (1,1,L,L)
    return _softmax_attend(q, k, v, mask, softcap=softcap)


# ---------------------------------------------------------------------------
# Streaming prefill (Algorithm 1): scan over token packs
# ---------------------------------------------------------------------------

def lpsa_prefill(
    x: jax.Array,
    qkv_proj: Callable[[jax.Array], tuple[jax.Array, jax.Array, jax.Array]],
    *,
    spec: LpsaSpec,
    num_q_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope: Callable[[jax.Array, jax.Array], jax.Array] | None = None,
    softcap: float | None = None,
    attend_fn: Callable | None = None,
    return_state: bool = False,
):
    """Pack-chunked fused projection + sparse attention (prefilling stage).

    x: (B, TL, Dm) hidden states.  qkv_proj maps an (B, C, Dm) pack to
    (q, k, v) already head-split: q (B,C,Hq,D), k/v (B,C,Hkv,D) — the ternary
    STL path lives inside the callable so DAS/TWD compose.  ``rope(x, pos)``
    applies positional rotation given absolute positions.

    Returns attention output (B, TL, Hq, D) — exactly equal to
    :func:`masked_attention_ref` on the same projections.
    """
    b, tl, _ = x.shape
    s, w, c = spec.sink, spec.window, spec.chunk
    if tl % c != 0:
        raise ValueError(f"TL={tl} must be divisible by the pack size C={c}")
    n_packs = tl // c
    n_rep = num_q_heads // num_kv_heads
    kvshape = lambda L: (b, L, num_kv_heads, head_dim)  # noqa: E731

    packs = x.reshape(b, n_packs, c, -1).swapaxes(0, 1)  # (N, B, C, Dm)

    def step(carry, pack):
        k_sink, v_sink, k_win, v_win, t0 = carry
        q, k, v = qkv_proj(pack)                     # STL cores (paper line 7/9/12)
        pos = t0 + jnp.arange(c)
        if rope is not None:
            q = rope(q, pos)
            k = rope(k, pos)

        # ---- update sink buffer (positions [0, s)) -------------------------
        slot = jnp.arange(s)
        take = (slot >= t0) & (slot < t0 + c)
        src = jnp.clip(slot - t0, 0, c - 1)
        tk = jnp.where(take[None, :, None, None], jnp.take(k, src, axis=1), k_sink)
        tv = jnp.where(take[None, :, None, None], jnp.take(v, src, axis=1), v_sink)

        # ---- assemble keys: [sink | window | current pack] -----------------
        win_pos = t0 - w + jnp.arange(w)             # may be negative => invalid
        k_all = jnp.concatenate([tk, k_win, k], axis=1)
        v_all = jnp.concatenate([tv, v_win, v], axis=1)
        k_pos = jnp.concatenate([jnp.arange(s), win_pos, pos])
        q_pos = pos

        # validity: a sink slot participates only once it belongs to a *prior*
        # pack (the current pack's own tokens go through the pack branch);
        # window slot valid iff pos >= s (dedupe vs sink) and >= 0.
        sink_valid = jnp.arange(s) < t0
        win_valid = (win_pos >= s) & (win_pos >= 0)
        pack_valid = jnp.ones((c,), dtype=bool)
        valid = jnp.concatenate([sink_valid, win_valid, pack_valid])

        mask = lpsa_allowed(q_pos[:, None], k_pos[None, :], s, w) & valid[None, :]
        kr = _repeat_kv(k_all, n_rep)
        vr = _repeat_kv(v_all, n_rep)
        attend = attend_fn if attend_fn is not None else _softmax_attend
        o = attend(q, kr, vr, mask[None, None], softcap=softcap)

        # ---- roll window buffer with the pack's trailing tokens ------------
        if c >= w:
            nk_win, nv_win = k[:, c - w:], v[:, c - w:]
        else:
            nk_win = jnp.concatenate([k_win[:, c:], k], axis=1)
            nv_win = jnp.concatenate([v_win[:, c:], v], axis=1)
        return (tk, tv, nk_win, nv_win, t0 + c), o

    init = (
        jnp.zeros(kvshape(s), x.dtype), jnp.zeros(kvshape(s), x.dtype),
        jnp.zeros(kvshape(w), x.dtype), jnp.zeros(kvshape(w), x.dtype),
        jnp.array(0, jnp.int32),
    )
    state, outs = jax.lax.scan(step, init, packs)    # (N, B, C, Hq, D)
    y = outs.swapaxes(0, 1).reshape(b, tl, num_q_heads, head_dim)
    if return_state:
        return y, state
    return y


# ---------------------------------------------------------------------------
# Decode: ring-buffered sink+window KV cache (O(TL_SA) memory at any length)
# ---------------------------------------------------------------------------

def decode_slot(pos: jax.Array, sink: int, window: int) -> jax.Array:
    """Cache slot for absolute position: sink slots are pinned, the window is
    a ring.  Slot layout: [0..sink) sink, [sink..sink+window) ring."""
    return jnp.where(pos < sink, pos, sink + (pos - sink) % window)


def lpsa_decode_attend(q, k_cache, v_cache, pos_cache, q_pos, *,
                       sink: int, window: int, softcap: float | None = None) -> jax.Array:
    """One-token sparse attention against the ring cache.

    q: (B, 1, Hq, D); caches: (B, sink+window, Hkv, D); pos_cache: (B, S+W)
    holding the absolute position stored in each slot (-1 = empty).  The new
    token's K/V must already be written to its slot (models/kvcache.py).
    """
    hq, hkv = q.shape[2], k_cache.shape[2]
    kr = _repeat_kv(k_cache, hq // hkv)
    vr = _repeat_kv(v_cache, hq // hkv)
    valid = pos_cache >= 0
    mask = lpsa_allowed(q_pos[:, None, None], pos_cache[:, None, :], sink, window)
    mask = (mask & valid[:, None, :])[:, None]       # (B,1,1,S+W) -> bhqk
    return _softmax_attend(q, kr, vr, mask, softcap=softcap)
