"""Analytic roofline performance / power model (paper Secs. IV-D, V-C).

Two uses:
  1. Reproduce the paper's hardware numbers (TENET-ASIC/FPGA vs A100/CPU:
     Figs 12-15, Table IV) from first principles — operator-level FLOP and
     byte counts with the optimizations (TWD / DAS / LPSA) toggled, pushed
     through a max(compute, memory) roofline and a power-integral energy model.
  2. Drive the TPU-facing DSE (core/dse.py) and sanity-check the dry-run
     roofline terms in EXPERIMENTS.md.

Everything is a pure function of dataclasses — no JAX dependency — so the
benchmarks stay trivially reproducible.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Literal

__all__ = [
    "HardwareSpec", "ModelShape", "TenetOpt",
    "TENET_ASIC", "TENET_FPGA", "A100_NAIVE", "A100_OPT", "CPU_I7", "TPU_V5E",
    "CPU_HOST",
    "LLAMA_1B3", "LLAMA_3B", "LLAMA_7B",
    "linear_cost", "attention_cost", "stage_cost", "e2e",
    "StageCost", "E2EReport",
    "backend_hw", "kernel_cost",
]

Stage = Literal["prefill", "decode"]


# ---------------------------------------------------------------------------
# Hardware
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_tops_low: float     # TOPS on the low-precision (ternary/int8) path
    peak_tops_high: float    # TOPS on the high-precision (fp16/bf16) path
    hbm_gbps: float          # off-chip bandwidth, GB/s
    power_w: float           # average board/chip power while busy
    onchip_mb: float = 8.0   # SRAM/VMEM capacity driving fusion legality
    flop_util: float = 1.0   # achieved/peak compute at one-batch inference
    bw_util: float = 1.0     # achieved/peak DRAM bandwidth, ditto


# TENET-ASIC (Table IV): 16 STL cores + 4 HP cores, each 32x64 MAC @ 500 MHz.
#   STL: 16*32*64*2 ops/cyc * 0.5 GHz = 32.8 TOPS ternary
#   HP :  4*32*64*2 ops/cyc * 0.5 GHz =  8.2 TOPS fp16
# Utilization factors model the paper's one-batch reality (Fig 2): commodity
# GPUs reach a fraction of peak at batch 1 (launch overheads, unfused
# attention, GEMV-shaped matmuls); TENET's dataflow sustains ~85-90%.
TENET_ASIC = HardwareSpec("tenet-asic", 32.8, 8.2, 512.0, 5.7, onchip_mb=1.4,
                          flop_util=0.85, bw_util=0.85)
# FPGA prototype: same architecture @400 MHz, half the core count (Sec. V-A)
TENET_FPGA = HardwareSpec("tenet-fpga", 13.1, 3.3, 512.0, 45.0, onchip_mb=1.4,
                          flop_util=0.85, bw_util=0.85)
A100_NAIVE = HardwareSpec("a100-naive", 312.0, 312.0, 1555.0, 300.0,
                          onchip_mb=40.0, flop_util=0.10, bw_util=0.22)
A100_OPT = HardwareSpec("a100-opt", 312.0, 312.0, 1555.0, 300.0,
                        onchip_mb=40.0, flop_util=0.35, bw_util=0.30)
CPU_I7 = HardwareSpec("i7-12700", 1.2, 1.2, 30.0, 65.0, onchip_mb=25.0,
                      flop_util=0.55, bw_util=0.80)
# TPU v5e-class chip (roofline constants used throughout EXPERIMENTS.md)
TPU_V5E = HardwareSpec("tpu-v5e", 394.0, 197.0, 819.0, 170.0, onchip_mb=128.0)
# Generic CI-runner host: what a single XLA-CPU thread pool sustains on the
# decode-shaped GEMMs the autotuner ranks (measured ~30 GFLOP/s effective on
# M<=8 matmuls, ~25 GB/s streaming) — coarse on purpose: kernel_cost() only
# has to order candidates, not predict absolute microseconds.
CPU_HOST = HardwareSpec("cpu-host", 0.03, 0.03, 25.0, 65.0, onchip_mb=16.0)

DRAM_PJ_PER_BYTE = 640.0     # HBM2 access energy  (paper cites >300x compute)
MAC_PJ_LOW = 0.2             # ternary MAC energy @28nm
MAC_PJ_HIGH = 1.5            # fp16 MAC energy @28nm


# ---------------------------------------------------------------------------
# Workload
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelShape:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    ffn_kind: str = "swiglu"   # swiglu => 3 mats, mlp => 2 mats

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def linear_params(self) -> int:
        """Ternary-quantizable parameters (QKV/O projections + FFN)."""
        d, f = self.d_model, self.d_ff
        kvd = self.n_kv_heads * self.head_dim
        attn = d * d + 2 * d * kvd + d * d       # Q, K, V, O
        ffn = (3 if self.ffn_kind == "swiglu" else 2) * d * f
        return self.n_layers * (attn + ffn)

    def embed_params(self) -> int:
        return self.vocab * self.d_model


LLAMA_1B3 = ModelShape("bitnet-1.3b", 24, 2048, 32, 32, 5460, 32000)
LLAMA_3B = ModelShape("bitnet-3b", 26, 3200, 32, 32, 8640, 32000)
LLAMA_7B = ModelShape("llama-7b", 32, 4096, 32, 32, 11008, 32000)


@dataclass(frozen=True)
class TenetOpt:
    """Optimization toggles (paper Fig 14 ablation order)."""
    weight_bits: float = 8.0   # 16 fp16 / 8 int8-naive / 2 int2 / 1.6 TWD
    das: bool = False          # activation N:M sparsity on linears
    s_a: float = 0.5           # surviving fraction under DAS
    lpsa: bool = False         # fused sparse attention
    tl_sa: int = 1024          # kept KV per row when lpsa
    act_bytes: int = 1         # int8 activations

    @staticmethod
    def naive_int8() -> "TenetOpt":
        return TenetOpt(weight_bits=8.0)

    @staticmethod
    def twd() -> "TenetOpt":
        return TenetOpt(weight_bits=1.6)

    @staticmethod
    def twd_das() -> "TenetOpt":
        return TenetOpt(weight_bits=1.6, das=True)

    @staticmethod
    def full() -> "TenetOpt":
        return TenetOpt(weight_bits=1.6, das=True, lpsa=True)


# ---------------------------------------------------------------------------
# Operator-level costs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StageCost:
    flops_low: float     # ternary-path ops
    flops_high: float    # fp16-path ops (attention)
    weight_bytes: float
    act_bytes: float     # activation + KV traffic to DRAM

    @property
    def bytes(self) -> float:
        return self.weight_bytes + self.act_bytes

    def __add__(self, o: "StageCost") -> "StageCost":
        return StageCost(self.flops_low + o.flops_low,
                         self.flops_high + o.flops_high,
                         self.weight_bytes + o.weight_bytes,
                         self.act_bytes + o.act_bytes)


def linear_cost(m: ModelShape, tokens: int, opt: TenetOpt) -> StageCost:
    """All ternary linears for `tokens` tokens (QKV/O + FFN + LM head)."""
    p = m.linear_params()
    sa = opt.s_a if opt.das else 1.0
    flops = 2.0 * p * tokens * sa
    wbytes = p * opt.weight_bits / 8.0
    # activations in/out of each linear, int8 (read x, write y), once per token
    d, f = m.d_model, m.d_ff
    nmat = 4 + (3 if m.ffn_kind == "swiglu" else 2)
    abytes = tokens * m.n_layers * (nmat * (d + f) / 2) * opt.act_bytes * 0.5
    # LM head (kept higher precision in BitNet; count fp16)
    head = 2.0 * m.embed_params() * tokens
    return StageCost(flops, head, wbytes + m.embed_params() * 2.0,
                     abytes)


def attention_cost(m: ModelShape, tl: int, new_tokens: int, opt: TenetOpt,
                   fused_onchip: bool) -> StageCost:
    """QK^T + SV for `new_tokens` queries against a TL-long context.

    ``fused_onchip``: LPSA keeps scores/intermediates in SRAM — activation
    traffic reduces to reading X once and writing O once; otherwise Q,K,V,S,O
    round-trip DRAM (the paper's Fig 4a 97% figure).
    """
    dh, h = m.head_dim, m.n_heads
    kv_len = min(tl, opt.tl_sa) if opt.lpsa else tl
    flops = 2.0 * 2.0 * h * dh * kv_len * new_tokens * m.n_layers  # QK + SV
    d = m.d_model
    kvd = m.n_kv_heads * dh
    if fused_onchip:
        act = new_tokens * m.n_layers * (d + d) * 2.0          # X in, O out
        act += new_tokens * m.n_layers * 2 * kvd * 2.0          # KV append
    else:
        # Q,K,V write+read, scores write+read (fp16), O write
        act = new_tokens * m.n_layers * (3 * d * 2 + d * 2) * 2.0
        act += new_tokens * m.n_layers * (2.0 * h * kv_len) * 2.0
        act += m.n_layers * 2 * kvd * kv_len * 2.0 * (1 if new_tokens == 1 else 0)
    if new_tokens == 1:  # decode reads the whole kept KV cache every token
        act += m.n_layers * 2 * kvd * kv_len * 2.0
    return StageCost(0.0, flops, 0.0, act)


def stage_cost(m: ModelShape, stage: Stage, tl: int, opt: TenetOpt,
               decode_tokens: int = 1) -> StageCost:
    if stage == "prefill":
        lin = linear_cost(m, tl, opt)
        att = attention_cost(m, tl, tl, opt, fused_onchip=opt.lpsa)
        return lin + att
    # decode: per generated token, weights stream once (memory-bound)
    lin = linear_cost(m, decode_tokens, opt)
    att = attention_cost(m, tl, 1, opt, fused_onchip=opt.lpsa)
    att = StageCost(att.flops_low * decode_tokens, att.flops_high * decode_tokens,
                    att.weight_bytes * decode_tokens, att.act_bytes * decode_tokens)
    # weights re-stream for every token
    lin = replace(lin, weight_bytes=lin.weight_bytes * decode_tokens)
    return lin + att


@dataclass(frozen=True)
class E2EReport:
    latency_s: float
    prefill_s: float
    decode_s: float
    energy_j: float
    tokens_per_s: float
    bytes_moved: float
    flops: float

    def ipj(self, ppl: float) -> float:
        from .ipj import ipj
        return ipj(self.tokens_per_s, ppl, self.energy_j
                   / max(self.latency_s, 1e-12))


def _roofline_latency(hw: HardwareSpec, c: StageCost) -> float:
    t_low = c.flops_low / (hw.peak_tops_low * 1e12 * hw.flop_util)
    t_high = c.flops_high / (hw.peak_tops_high * 1e12 * hw.flop_util)
    t_mem = c.bytes / (hw.hbm_gbps * 1e9 * hw.bw_util)
    # low/high engines pipeline (LPSA hides attention under projection) but
    # both contend with DRAM: classic max() roofline.
    return max(t_low + 0.15 * t_high, t_high, t_mem)


# ---------------------------------------------------------------------------
# Kernel-candidate cost model (feeds kernels/autotune.py)
# ---------------------------------------------------------------------------
#
# The DSE machinery above prices whole serving stages; the autotuner needs the
# same roofline logic one level down — "which tile config / implementation of
# ONE kernel call is fastest on THIS backend".  kernel_cost() prices a single
# (ternary_gemm | das_ternary_gemm | sparse_attn) invocation for a named
# implementation.  Only the *ordering* matters: autotune ranks candidates with
# this model, then confirms the top few with real timed runs.

# effective FLOPs per decoded trit for the base-3 unpack (measured on XLA-CPU:
# the int32 div/mod chain costs ~3x the float divide-free variant)
_DECODE_OPS = {"plain": 8.0, "f32dec": 3.0, "pallas": 6.0}
# intermediate bytes written+read per decoded trit (XLA materializes the int32
# digit stack for "plain"; "f32dec" stays in registers feeding the sub-GEMMs)
_DECODE_BYTES = {"plain": 12.0, "f32dec": 1.6, "pallas": 0.0}
# random-gather effective-bandwidth slowdown vs streaming reads
_GATHER_SLOWDOWN = {"cpu": 15.0, "gpu": 2.0, "tpu": 4.0}
# Pallas interpreter (emulation) penalty: never competitive with a compiled
# path, but still ranked so interpret-only tuning (CI) orders tile shapes
_INTERPRET_PENALTY = 2000.0
_STEP_OVERHEAD_S = 2e-6      # per grid-step / per-chunk dispatch overhead
TRITS_PER_BYTE_F = 5.0


def backend_hw(backend: str) -> HardwareSpec:
    """HardwareSpec used to rank kernel candidates on a JAX backend name."""
    return {"tpu": TPU_V5E, "gpu": A100_OPT}.get(backend, CPU_HOST)


def kernel_cost(hw: HardwareSpec, op: str, impl: str, *, m: int = 1,
                k: int = 0, n: int = 0, keep: int = 0, block: int = 32,
                block_m: int = 0, block_n: int = 0, block_k: int = 0,
                hq: int = 0, hkv: int = 0, lq: int = 0, lk: int = 0,
                d: int = 0) -> float:
    """Estimated seconds for one kernel call under implementation `impl`.

    GEMM ops (`ternary_gemm`, `das_ternary_gemm`): (M, K) x packed (K/5, N).
    `keep`/`block` describe DAS compaction (keep=0 => dense).  `block_*` are
    Pallas tile shapes (0 => kernel defaults).  `sparse_attn`: hq/hkv heads,
    lq queries vs lk keys of head dim d; `block_k` doubles as the XLA flash
    kv-chunk.  Implementations: "pallas"/"interpret" (tiled kernels),
    "xla_plain"/"xla_f32dec" (dense decode-GEMM), "xla_dense_plain"/
    "xla_dense_f32dec" (DAS mask densify + decode-GEMM), "xla_gather"
    (per-row gather of kept lanes), "xla_flash" (chunked online-softmax).
    """
    peak = hw.peak_tops_low * 1e12 * hw.flop_util
    bw = hw.hbm_gbps * 1e9 * hw.bw_util
    gather_bw = bw / _GATHER_SLOWDOWN.get(hw.name.split("-")[0], 10.0)

    if op in ("ternary_gemm", "das_ternary_gemm"):
        trits = float(k) * n
        sa = keep / block if keep else 1.0
        flops = 2.0 * m * k * n                      # dense-K slab dot
        bytes_ = trits / TRITS_PER_BYTE_F + m * k * 4.0 + m * n * 4.0
        if impl in ("pallas", "interpret"):
            bm = block_m or min(8, m)
            bn = block_n or min(256, n)
            # decode + scatter re-run once per M-tile x N-tile of the grid
            flops += (_DECODE_OPS["pallas"] * trits + m * k * max(keep, 1)) \
                * max(1, -(-m // bm))
            steps = max(1, -(-m // bm)) * max(1, -(-n // bn)) \
                * max(1, k // (320 * max(block_k, 1)))
            t = flops / peak + bytes_ / bw + steps * _STEP_OVERHEAD_S
            return t * (_INTERPRET_PENALTY if impl == "interpret" else 1.0)
        if impl == "xla_gather":
            # decode everything, then per-row gather of the kept K lanes
            flops = 2.0 * m * (k * sa) * n + _DECODE_OPS["plain"] * trits
            bytes_ += m * (k * sa) * n * 4.0 * (bw / gather_bw)
            return flops / peak + bytes_ / bw
        dec = "plain" if impl.endswith("plain") else "f32dec"
        flops += _DECODE_OPS[dec] * trits
        bytes_ += _DECODE_BYTES[dec] * trits
        if impl.startswith("xla_dense"):             # DAS mask prep
            flops += float(m) * k * block
        return flops / peak + bytes_ / bw

    if op == "sparse_attn":
        flops = 4.0 * hq * lq * lk * d
        bytes_ = 2.0 * hkv * lk * d * 4.0 + 2.0 * hq * lq * d * 4.0
        if impl == "xla_flash":
            chunk = block_k or min(512, lk)
            steps = max(1, -(-lk // chunk))
        else:                                        # pallas / interpret
            bq = min(block_m or 128, max(lq, 1))
            bk = min(block_k or 128, max(lk, 1))
            steps = hq * max(1, -(-lq // bq)) * max(1, -(-lk // bk))
        t = flops / peak + bytes_ / bw + steps * _STEP_OVERHEAD_S
        return t * (_INTERPRET_PENALTY if impl == "interpret" else 1.0)

    raise ValueError(f"kernel_cost: unknown op {op!r}")


def e2e(m: ModelShape, hw: HardwareSpec, opt: TenetOpt, *, prefill_tl: int,
        decode_tokens: int) -> E2EReport:
    cp = stage_cost(m, "prefill", prefill_tl, opt)
    cd = stage_cost(m, "decode", prefill_tl + decode_tokens, opt,
                    decode_tokens=decode_tokens)
    tp = _roofline_latency(hw, cp)
    td = _roofline_latency(hw, cd)
    lat = tp + td
    energy = hw.power_w * lat + DRAM_PJ_PER_BYTE * 1e-12 * (cp.bytes + cd.bytes)
    total = cp + cd
    return E2EReport(latency_s=lat, prefill_s=tp, decode_s=td, energy_j=energy,
                     tokens_per_s=decode_tokens / max(td, 1e-12),
                     bytes_moved=total.bytes,
                     flops=total.flops_low + total.flops_high)
