"""LPSA vs naive serving: KV-cache memory + decode-step cost on one model.

Shows the paper's Sec. IV-B claim concretely: the ring cache is O(TL_SA)
regardless of context, while the naive cache grows with the sequence.

Run:  PYTHONPATH=src python examples/lpsa_vs_full.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import model as MD
from repro.models.transformer import Runtime

cfg = reduced(get_config("bitnet-1.3b"))
params = MD.export_serving(MD.init_params(jax.random.PRNGKey(0), cfg), cfg)
B = 2

for ctx in (256, 1024, 4096):
    row = [f"ctx={ctx:5d}"]
    for sparse in (False, True):
        rt = Runtime(serve_sparse=sparse)
        caches = MD.init_caches(None, cfg, B, ctx, rt, jnp.float32)
        nbytes = sum(x.nbytes for x in jax.tree.leaves(caches))
        step = jax.jit(lambda s, c, tk, t: MD.decode_step(s, cfg, c, tk, t, rt))
        tok = jnp.zeros((B,), jnp.int32)
        lg, caches = step(params, caches, tok, jnp.array(ctx - 1))
        jax.block_until_ready(lg)
        t0 = time.perf_counter()
        for i in range(5):
            lg, caches = step(params, caches, tok, jnp.array(ctx - 1))
        jax.block_until_ready(lg)
        dt = (time.perf_counter() - t0) / 5
        row.append(f"{'LPSA-ring' if sparse else 'full-cache'}: "
                   f"{nbytes/2**20:7.2f} MiB  {dt*1e3:7.2f} ms/step")
    print(" | ".join(row))
print("\nring cache is O(sink+window) at any context; full cache is O(ctx).")
