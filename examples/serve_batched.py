"""End-to-end driver: serve a ~110M-parameter Sparse-BitNet on CPU.

Builds the model, exports TWD-packed serving weights, prefills a batch of
requests through the LPSA streaming dataflow and generates tokens greedily
from the O(TL_SA) ring caches — the paper's full serving path, minus the
accelerator.

Run:  PYTHONPATH=src python examples/serve_batched.py [--gen 16]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DasConfig, LpsaConfig, ModelConfig, TernaryConfig
from repro.models import model as MD
from repro.models.transformer import Runtime

CFG_100M = ModelConfig(
    name="sparse-bitnet-110m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
    d_ff=2048, vocab=32_000,
    ternary=TernaryConfig(das=DasConfig(32, 16)),
    lpsa=LpsaConfig(sink=32, window=224, chunk=64),
    dtype="float32", remat=False, scan_layers=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = CFG_100M
    rt = Runtime()

    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    sparams = MD.export_serving(params, cfg)
    nb = sum(x.nbytes for x in jax.tree.leaves(sparams))
    print(f"[serve] {cfg.name}: {n/1e6:.0f}M params -> {nb/2**20:.0f} MiB "
          f"packed serving weights")

    prefill = jax.jit(lambda s, x: MD.prefill(
        s, cfg, x, rt, max_len=args.prompt_len + args.gen))
    decode = jax.jit(lambda s, c, tk, t: MD.decode_step(s, cfg, c, tk, t, rt))

    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.perf_counter()
    logits, caches = prefill(sparams, toks)
    jax.block_until_ready(logits)
    t_pre = time.perf_counter() - t0
    print(f"[serve] prefill {args.batch}x{args.prompt_len}: {t_pre:.2f}s "
          f"({args.batch*args.prompt_len/t_pre:.0f} tok/s)")

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, caches = decode(sparams, caches, tok,
                                jnp.array(args.prompt_len + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t0
    print(f"[serve] decode {args.gen-1} x {args.batch}: {t_dec:.2f}s "
          f"({(args.gen-1)*args.batch/t_dec:.1f} tok/s)")
    print(f"[serve] sample continuation ids: "
          f"{np.asarray(jnp.stack(out,1))[0][:12].tolist()}")


if __name__ == "__main__":
    main()
