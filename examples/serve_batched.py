"""Continuous batching demo: staggered requests through repro.serve.

Builds a small Sparse-BitNet, exports TWD-packed serving weights, then
replays one trace of requests with different prompt lengths, generation
budgets, and arrival times through the continuous-batching engine — a
request prefills into a freed slot while the other slots keep decoding —
and through the lock-step ("wave") baseline for comparison.  Reports
per-request latency and aggregate decode tok/s for both.

Run:  PYTHONPATH=src python examples/serve_batched.py [--gen 16]
"""
import argparse

import jax
import numpy as np

from repro.configs.base import DasConfig, LpsaConfig, ModelConfig, TernaryConfig
from repro.models import model as MD
from repro.models.transformer import Runtime
from repro.serve import Request, ServeConfig, ServeEngine

CFG_100M = ModelConfig(
    name="sparse-bitnet-110m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
    d_ff=2048, vocab=32_000,
    ternary=TernaryConfig(das=DasConfig(32, 16)),
    lpsa=LpsaConfig(sink=32, window=224, chunk=64),
    dtype="float32", remat=False, scan_layers=False,
)


def make_trace(cfg, gen: int, seed: int = 1):
    """Mixed prompt/gen lengths, staggered arrivals (vtime = decode steps)."""
    rng = np.random.default_rng(seed)
    spec = [  # (prompt_len, max_new_tokens, arrival)
        (128, gen, 0),
        (64, gen + 12, 0),
        (96, max(1, gen // 3), 2),
        (192, gen, 5),
        (48, gen + 8, 8),
        (128, max(1, gen // 3), 10),
        (32, gen + 4, 14),
        (80, max(1, gen // 2), 18),
    ]
    return [Request(uid=i,
                    prompt=np.asarray(rng.integers(0, cfg.vocab, p), np.int32),
                    max_new_tokens=g, arrival=a)
            for i, (p, g, a) in enumerate(spec)]


def run_policy(cfg, sparams, rt, trace, policy, *, slots, max_len):
    eng = ServeEngine(cfg, sparams, rt,
                      config=ServeConfig(max_slots=slots, max_len=max_len,
                                         policy=policy))
    return eng, eng.timed_replay(trace)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = CFG_100M
    rt = Runtime()

    params = MD.init_params(jax.random.PRNGKey(0), cfg)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    sparams = MD.export_serving(params, cfg)
    nb = sum(x.nbytes for x in jax.tree.leaves(sparams))
    print(f"[serve] {cfg.name}: {n/1e6:.0f}M params -> {nb/2**20:.0f} MiB "
          f"packed serving weights")

    trace = make_trace(cfg, args.gen)
    max_len = max(r.prompt_len + r.max_new_tokens for r in trace)

    tput = {}
    for policy in ("wave", "continuous"):
        eng, results = run_policy(cfg, sparams, rt, trace, policy,
                                  slots=args.slots, max_len=max_len)
        st = eng.stats
        tput[policy] = st.generated_tokens / max(st.wall_seconds, 1e-9)
        lat = [results[r.uid].latency_steps for r in trace]
        print(f"\n[{policy}] {st.decode_steps} decode steps, slot util "
              f"{st.slot_utilization:.2f}, {st.generated_tokens} tokens, "
              f"{st.wall_seconds:.2f}s ({tput[policy]:.1f} tok/s), "
              f"latency p50/max {int(np.median(lat))}/{max(lat)} steps")
        for r in trace:
            res = results[r.uid]
            joined = (f"mid-decode ({res.admitted_with_active} slots were "
                      f"generating)" if res.admitted_with_active
                      else f"at vtime {res.admit_vtime}")
            print(f"  req {r.uid}: prompt {r.prompt_len:>3}, arrival "
                  f"{r.arrival:>2}, admitted {joined}, ttft "
                  f"{res.ttft_steps} steps, done at {res.finish_vtime}")

    speedup = tput["continuous"] / max(tput["wave"], 1e-9)
    print(f"\n[serve] continuous vs lock-step aggregate throughput: "
          f"{speedup:.2f}x")


if __name__ == "__main__":
    main()
