"""Train a Sparse-BitNet with the paper's QAT recipe (STE ternary + DAS),
with fault-tolerant checkpointing — kill and restart freely.

Run:  PYTHONPATH=src python examples/train_ternary_qat.py
"""
from repro.launch import train

train.main([
    "--arch", "bitnet-1.3b", "--reduced",
    "--steps", "60", "--batch", "8", "--seq", "64",
    "--ckpt-dir", "/tmp/tenet_qat_ckpt", "--ckpt-every", "20",
    "--inject-failure", "31",       # survive a simulated node loss
    "--log-every", "20",
])
