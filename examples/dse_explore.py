"""Run the paper's Design Space Exploration (Sec. IV-D) + the TPU variant.

Run:  PYTHONPATH=src python examples/dse_explore.py
"""
from repro.core import dse, perfmodel as pm

print("== TENET-ASIC DSE (Eq. 4: PPL x power x latency, s.t. Eq. 7) ==")
for c in dse.dse_grid_search(pm.LLAMA_3B, "bitnet-3b")[:5]:
    print(f"  P_L={c.p_l:2d} P_H={c.p_h} TL_SA={c.tl_sa:4d} S_a={c.s_a:.2f} "
          f"ppl={c.ppl:5.2f} power={c.power_w:5.2f}W "
          f"lat={c.latency_s*1e3:6.2f}ms obj={c.objective:.3e}")

print("\n== TPU v5e variant: (pack C, TL_SA, S_a) roofline balance ==")
for r in dse.tpu_dse_grid_search(pm.LLAMA_3B, "bitnet-3b", pm.TPU_V5E)[:5]:
    print(f"  C={r['chunk']:3d} TL_SA={r['tl_sa']:4d} S_a={r['s_a']:.1f} "
          f"t_proj={r['t_proj']*1e6:6.1f}us t_attn={r['t_attn']*1e6:6.1f}us "
          f"attention-hidden={r['hidden']}")
