"""Quickstart: the TENET stack in five steps on CPU.

  1. ternary-quantize a weight (Q_1.58, BitNet absmean rule)
  2. pack it base-3 (TWD, 1.6 bits/weight) and decode it back
  3. DAS: keep the top 16/32 activations per block
  4. run the fused ternary GEMM kernel (Pallas, interpret mode)
  5. forward a reduced Sparse-BitNet through the full model API

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import das, ternary, twd
from repro.kernels import ops
from repro.configs import get_config, reduced
from repro.models import model as MD
from repro.models.transformer import Runtime

# 1. ternary quantization -----------------------------------------------
w = jax.random.normal(jax.random.PRNGKey(0), (640, 256))
tw = ternary.ternary_quantize(w)
zeros = float((tw.values == 0).mean())
print(f"[1] Q_1.58: scale={float(tw.scale):.4f}, {zeros:.0%} zeros "
      f"(paper: 30-40%)")

# 2. TWD packing ---------------------------------------------------------
packed = twd.pack_ternary(tw.values)
bits = packed.size * 8 / tw.values.size
roundtrip = np.array_equal(np.asarray(twd.unpack_ternary(packed, 640)),
                           np.asarray(tw.values))
print(f"[2] TWD: {bits:.2f} bits/weight (vs 2.0 int2), roundtrip={roundtrip}")

# 3. DAS -----------------------------------------------------------------
x = jax.random.normal(jax.random.PRNGKey(1), (4, 640))
mask = das.das_mask(x, block_size=32, keep=16)
print(f"[3] DAS: S_a = {float(mask.mean()):.2f} (16-of-32 per block)")

# 4. fused kernel --------------------------------------------------------
y_kernel = ops.ternary_gemm(das.das_apply(x, mask), packed, tw.scale,
                            mode="interpret")
y_ref = das.das_apply(x, mask) @ (tw.values * tw.scale)
print(f"[4] fused TWD+GEMM kernel: max err vs dense = "
      f"{float(jnp.abs(y_kernel - y_ref).max()):.2e}")

# 5. whole model ---------------------------------------------------------
cfg = reduced(get_config("bitnet-1.3b"))
params = MD.init_params(jax.random.PRNGKey(0), cfg)
sparams = MD.export_serving(params, cfg)   # offline TWD encoder
toks = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab)
logits, caches = MD.prefill(sparams, cfg, toks, Runtime(), max_len=40)
print(f"[5] Sparse-BitNet prefill OK: logits {logits.shape}, "
      f"ring-cache slots = {caches['tail'][0]['k'].shape[1]} "
      f"(sink {cfg.lpsa.sink} + window {cfg.lpsa.window})")
